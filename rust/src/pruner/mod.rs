//! The Twilight Pruner (paper §4.1–4.2): the second stage of the
//! Select-then-Prune architecture.
//!
//! Given the candidate token set chosen by a (black-box) Token Selector
//! under a conservative budget, the pruner:
//! 1. estimates attention logits for the candidates from the INT4 mirror
//!    K cache (page-tiled SpGEMV, Appendix B.1);
//! 2. softmax-normalizes them (top-p requires normalized weights —
//!    Table 1's "Need Normalization?" column);
//! 3. runs top-p binary search (Algorithm 1) to keep the minimal subset
//!    with cumulative estimated mass ≥ p;
//! 4. under GQA, unions the per-query-head keep-sets across the group so
//!    the group-varlen attention kernel loads each KV row once (B.2).
//!
//! **Hot path.** The engine calls [`prune_group_into`], which leaves the
//! union and per-head outcomes in the caller's [`AttnScratch`] arena —
//! every buffer the pipeline touches (SpGEMV tiles and qsums, softmax
//! rows, the binary search's active set, the min-keep floor's order, the
//! keep-set union, the recycled [`PruneOutcome`] vectors) is reused
//! across calls, so steady-state decode performs **zero heap
//! allocations** per pruned attention call (pinned by
//! `rust/tests/alloc_count.rs`). [`prune_head`] / [`prune_group`] are
//! thin compatibility wrappers that clone the results out.
//!
//! **Hierarchical page-level pre-prune** (opt-in:
//! `PrunerConfig::hier_pages`, surfaced as `--hier-pages` /
//! `TWILIGHT_HIER_PAGES` and a `BudgetDirective` knob). Before SpGEMV,
//! each candidate page's maximum *estimated* logit is upper-bounded from
//! the cache's Quest min/max metadata plus the mirror block's
//! quantization slack; pages are scored in descending bound order, and
//! scoring stops once the banked softmax mass proves the remaining pages
//! cannot shift any head's top-p mass by more than
//! [`PrunerConfig::hier_eps`] — so the kept set's captured mass (w.r.t.
//! the full candidate softmax) stays ≥ `p − hier_eps`. Skipped-page
//! counts flow into `SignalHub` / `EngineStats` / `ServingReport`
//! telemetry. With nothing skipped the hier path is bit-identical to the
//! default path (scores are scattered back to candidate order before the
//! softmax), which is also why default mode is pinned: `hier_pages:
//! false` never reorders anything.

pub mod topp;

use crate::attention::spgemv::{
    estimate_scores, estimate_scores_group, estimate_scores_group_with_qsums, run_end,
    sealed_limit, SpgemvScratch,
};
use crate::kvcache::{PagedKvCache, SeqCache};
use crate::tensor::quant::{self, QuantBits};
use topp::{topp_binary_search_into, topp_sort, ToppScratch};

/// Pruner configuration.
#[derive(Clone, Copy, Debug)]
pub struct PrunerConfig {
    /// Cumulative-mass threshold p (paper: 0.95 LLaMA, 0.85 Longchat).
    pub p: f32,
    /// Binary-search convergence epsilon.
    pub eps: f32,
    /// Never prune below this many tokens (attention sinks + stability).
    pub min_keep: usize,
    /// Use the sort oracle instead of binary search (ablations).
    pub use_sort: bool,
    /// Hierarchical page-level top-p pre-prune (see module docs). Off by
    /// default: the default pipeline is bit-exact with the historical
    /// row-major path.
    pub hier_pages: bool,
    /// Mass tolerance of the page pre-prune: scoring stops only when the
    /// unscored pages provably cannot change any head's captured top-p
    /// mass by more than this, so kept mass ≥ p − hier_eps.
    pub hier_eps: f32,
}

impl Default for PrunerConfig {
    fn default() -> Self {
        PrunerConfig {
            p: 0.95,
            eps: 1e-4,
            min_keep: 4,
            use_sort: false,
            hier_pages: false,
            hier_eps: 0.02,
        }
    }
}

/// Outcome of pruning one query head.
#[derive(Clone, Debug, Default)]
pub struct PruneOutcome {
    /// Kept logical token indices (subset of the candidates), ascending.
    pub kept: Vec<usize>,
    /// Estimated attention mass captured (within the candidate set).
    pub mass: f32,
    /// Estimated softmax weight (over the candidate set) of each kept
    /// token, aligned with `kept`; sums to `mass`. Empty when the pruner
    /// short-circuited (candidates ≤ min_keep) without scoring — callers
    /// that need weights must fall back to exact scores in that case.
    pub weights: Vec<f32>,
    /// Binary search iterations.
    pub iters: usize,
}

/// Page-level accounting of one hierarchical prune call: how many
/// candidate page runs existed and how many were skipped unscored.
/// All-zero when the hier pre-prune is disabled.
#[derive(Clone, Copy, Debug, Default)]
pub struct HierPruneInfo {
    pub pages_total: u32,
    pub pages_skipped: u32,
}

/// The per-worker scratch arena of the pruned-attention hot path (grown
/// from the historical `PrunerScratch`; that name survives as an alias).
/// One instance per attention worker, threaded through selection
/// (`TokenSelector::select_into`), pruning ([`prune_group_into`]), the
/// sparse kernel (`attention::sparse::group_varlen_with`), and the
/// stateful-selector observation feedback. Every buffer's capacity only
/// grows, so steady-state decode performs zero heap allocations per
/// (item × kv-head) work unit.
#[derive(Default)]
pub struct AttnScratch {
    /// Single-head score buffer ([`prune_head`]).
    scores: Vec<f32>,
    /// Group score matrix, `[group][candidates]` flattened.
    group_scores: Vec<f32>,
    /// SpGEMV tile / qsum / row staging.
    pub spgemv: SpgemvScratch,
    /// Top-p binary search buffers.
    pub topp: ToppScratch,
    /// Min-keep floor's partial-selection order.
    floor_order: Vec<usize>,
    /// Stage-1 candidate buffer (filled by `TokenSelector::select_into`).
    pub candidates: Vec<usize>,
    /// Keep-set union across the GQA group (ascending, deduped) —
    /// the result of the latest [`prune_group_into`].
    pub union: Vec<usize>,
    /// Per-head outcomes of the latest [`prune_group_into`]; element
    /// vectors are recycled in place across calls.
    pub outcomes: Vec<PruneOutcome>,
    /// Streaming-softmax state for `group_varlen_with`.
    pub attn_m: Vec<f32>,
    /// Streaming-softmax denominators for `group_varlen_with`.
    pub attn_denom: Vec<f32>,
    /// Observation-feedback weight staging (engine).
    pub obs_w: Vec<f32>,
    /// Hierarchical page pre-prune state.
    hier: HierScratch,
    /// Bound-guided sparse-prefill state (`attention::prefill`).
    pub sprefill: crate::attention::prefill::SparsePrefillScratch,
}

/// Historical name of the arena (pre-dating the attention/selector
/// buffers); kept so existing callers compile unchanged.
pub type PrunerScratch = AttnScratch;

/// One per-page run of candidate indices (hier pre-prune).
#[derive(Clone, Copy, Default)]
struct RunInfo {
    /// Candidate-index range `[start, end)`.
    start: usize,
    end: usize,
    /// Ordering key: max over the group of the scaled logit upper bound
    /// (+∞ for unsealed-tail runs, which are always scored first).
    key: f32,
}

#[derive(Default)]
struct HierScratch {
    runs: Vec<RunInfo>,
    /// Run visit order (descending bound).
    order: Vec<usize>,
    /// Per-(run × head) scaled logit upper bounds.
    bounds: Vec<f32>,
    /// Per-candidate "was scored" marks.
    scored: Vec<bool>,
    /// Per-run scoring staging, `[group][run_len]`.
    run_out: Vec<f32>,
    /// Scored candidate positions, ascending.
    compact_pos: Vec<usize>,
    /// Token ids of the scored candidates, ascending (aligned with
    /// `compact_pos`).
    compact_cands: Vec<usize>,
    /// Scored score matrix, `[group][compact]`.
    compact_scores: Vec<f32>,
    /// Streaming per-head scaled-logit max / exp-sum (stop rule only —
    /// the final softmax is recomputed from the compact scores, so f64
    /// here cannot perturb the numerics).
    m: Vec<f64>,
    s: Vec<f64>,
    /// Per-head `Σ|q_i|` (quantization-slack term of the page bound).
    qabs: Vec<f32>,
    /// Per-head max finite bound (shift for the suffix sums below).
    bmax: Vec<f32>,
    /// Per-(order position × head) suffix sums of
    /// `len · exp(bound − bmax)` over the not-yet-visited runs: fixed
    /// after ordering, so each stop check is O(group) instead of
    /// rescanning the remaining tail (O(runs²·group) worst case).
    suffix: Vec<f64>,
}

/// Reuse the outcome vector in place: truncate/extend to `group`,
/// clearing each element's buffers without freeing them.
fn reset_outcomes(outs: &mut Vec<PruneOutcome>, group: usize) {
    outs.truncate(group);
    for o in outs.iter_mut() {
        o.kept.clear();
        o.weights.clear();
        o.mass = 0.0;
        o.iters = 0;
    }
    while outs.len() < group {
        outs.push(PruneOutcome::default());
    }
}

/// Prune `candidates` for a single query head `q` against `kv_head`'s
/// mirror cache. Returns the kept subset (minimal top-p set). Not the
/// engine hot path (that is [`prune_group_into`]); the returned outcome
/// owns its vectors.
pub fn prune_head(
    cfg: &PrunerConfig,
    cache: &PagedKvCache,
    seq: &SeqCache,
    kv_head: usize,
    q: &[f32],
    candidates: &[usize],
    scratch: &mut AttnScratch,
) -> PruneOutcome {
    let n = candidates.len();
    if n <= cfg.min_keep {
        return PruneOutcome { kept: candidates.to_vec(), mass: 1.0, weights: Vec::new(), iters: 0 };
    }
    scratch.scores.resize(n, 0.0);
    // (1) SpGEMV estimation from the INT4 mirror (page-tiled).
    estimate_scores(
        cache,
        seq,
        kv_head,
        q,
        candidates,
        &mut scratch.scores,
        &mut scratch.spgemv,
    );
    // (2) scale + softmax, (3) top-p, (4) min_keep floor — shared with
    // the group path. The union buffer doubles as throwaway here.
    let s = crate::attention::scale(q.len());
    let mut out = PruneOutcome::default();
    scratch.union.clear();
    finish_head(
        &mut scratch.scores,
        candidates,
        cfg,
        s,
        &mut scratch.topp,
        &mut scratch.floor_order,
        &mut out,
        &mut scratch.union,
    );
    out
}

/// Scale → softmax → top-p → min-keep floor for one head's score row
/// (shared by the default and hierarchical paths; `row` holds raw
/// estimated logits on entry and normalized weights on exit). Appends
/// the kept tokens to `union`.
#[allow(clippy::too_many_arguments)]
fn finish_head(
    row: &mut [f32],
    cands: &[usize],
    cfg: &PrunerConfig,
    scale: f32,
    topp_s: &mut ToppScratch,
    order: &mut Vec<usize>,
    out: &mut PruneOutcome,
    union: &mut Vec<usize>,
) {
    for x in row.iter_mut() {
        *x *= scale;
    }
    crate::tensor::softmax_inplace(row);
    let (mass0, iters) = if cfg.use_sort {
        let r = topp_sort(row, cfg.p);
        topp_s.indices.clear();
        topp_s.indices.extend_from_slice(&r.indices);
        (r.mass, r.iters)
    } else {
        let st = topp_binary_search_into(row, cfg.p, cfg.eps, topp_s);
        (st.mass, st.iters)
    };
    out.mass = floor_min_keep_into(
        row,
        cands,
        &topp_s.indices,
        mass0,
        cfg.min_keep,
        order,
        &mut out.kept,
        &mut out.weights,
    );
    out.iters = iters;
    union.extend_from_slice(&out.kept);
}

/// Apply the `min_keep` floor to a top-p result: when fewer than
/// `min_keep` tokens survived, keep the `min_keep` top-scoring candidates
/// instead — and recompute the captured mass over the floored set. The
/// governor steers on `PruneOutcome::mass`, so reporting the pre-floor
/// mass would understate what the kept set actually captures exactly when
/// the floor is active (peaked heads), biasing the controller. Also
/// returns each kept token's estimated softmax weight (aligned with the
/// kept list) so downstream consumers — the SnapKV/H2O observation
/// feedback — never have to re-score what the pruner already scored.
///
/// The floor uses `select_nth_unstable_by` partial selection (not a full
/// sort) under a (score desc, index asc) total order — the same set, and
/// after the small re-sort the same summation sequence, as the historical
/// stable full sort, so the reported mass is fp-identical.
#[allow(clippy::too_many_arguments)]
fn floor_min_keep_into(
    scores: &[f32],
    candidates: &[usize],
    topp_indices: &[usize],
    topp_mass: f32,
    min_keep: usize,
    order: &mut Vec<usize>,
    kept: &mut Vec<usize>,
    weights: &mut Vec<f32>,
) -> f32 {
    kept.clear();
    weights.clear();
    if topp_indices.len() >= min_keep {
        kept.extend(topp_indices.iter().map(|&i| candidates[i]));
        weights.extend(topp_indices.iter().map(|&i| scores[i]));
        return topp_mass;
    }
    let n = scores.len();
    let m = min_keep.min(n);
    order.clear();
    order.extend(0..n);
    let by = |a: &usize, b: &usize| {
        scores[*b]
            .partial_cmp(&scores[*a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(b))
    };
    if m < n {
        order.select_nth_unstable_by(m, by);
        order.truncate(m);
    }
    // Restore the descending visit order so the mass sums in the same
    // fp sequence the full sort produced.
    order.sort_unstable_by(by);
    let mass = order.iter().map(|&i| scores[i]).sum();
    // Candidates are ascending, so sorting the score-indices restores
    // ascending kept order with weights still aligned.
    order.sort_unstable();
    kept.extend(order.iter().map(|&i| candidates[i]));
    weights.extend(order.iter().map(|&i| scores[i]));
    mass
}

/// Prune for a GQA group: `qs` is `[group * d]` query heads sharing
/// `kv_head`. Per-head top-p keep-sets are unioned (B.2) so the attention
/// kernel loads each KV row once per group. Compatibility wrapper over
/// [`prune_group_into`]: returns owned copies of the union (ascending)
/// and the per-head outcomes.
#[allow(clippy::too_many_arguments)]
pub fn prune_group(
    cfg: &PrunerConfig,
    cache: &PagedKvCache,
    seq: &SeqCache,
    kv_head: usize,
    qs: &[f32],
    group: usize,
    candidates: &[usize],
    scratch: &mut AttnScratch,
) -> (Vec<usize>, Vec<PruneOutcome>) {
    prune_group_into(cfg, cache, seq, kv_head, qs, group, candidates, scratch);
    (scratch.union.clone(), scratch.outcomes.clone())
}

/// Allocation-free group prune: results land in `scratch.union`
/// (ascending, deduped) and `scratch.outcomes` (one per head, buffers
/// recycled). Returns the page-level accounting of the hierarchical
/// pre-prune (all-zero when `cfg.hier_pages` is off).
#[allow(clippy::too_many_arguments)]
pub fn prune_group_into(
    cfg: &PrunerConfig,
    cache: &PagedKvCache,
    seq: &SeqCache,
    kv_head: usize,
    qs: &[f32],
    group: usize,
    candidates: &[usize],
    scratch: &mut AttnScratch,
) -> HierPruneInfo {
    let d = qs.len() / group;
    let n = candidates.len();
    reset_outcomes(&mut scratch.outcomes, group);
    scratch.union.clear();
    if n <= cfg.min_keep {
        scratch.union.extend_from_slice(candidates);
        for o in scratch.outcomes.iter_mut() {
            o.kept.extend_from_slice(candidates);
            o.mass = 1.0;
        }
        return HierPruneInfo::default();
    }
    let s = crate::attention::scale(d);
    if cfg.hier_pages {
        return hier_prune_group(cfg, cache, seq, kv_head, qs, group, candidates, s, scratch);
    }
    // One page-tiled SpGEMV pass for the whole group (codes unpacked once
    // per page run — §Perf); then per-head softmax + top-p on the shared
    // score matrix.
    let ts = crate::obs::trace::timer();
    scratch.group_scores.resize(group * n, 0.0);
    estimate_scores_group(
        cache,
        seq,
        kv_head,
        qs,
        group,
        candidates,
        &mut scratch.group_scores,
        &mut scratch.spgemv,
    );
    crate::obs::trace::stop_ctx(ts, crate::obs::trace::Stage::Spgemv);
    let tf = crate::obs::trace::timer();
    for g in 0..group {
        finish_head(
            &mut scratch.group_scores[g * n..(g + 1) * n],
            candidates,
            cfg,
            s,
            &mut scratch.topp,
            &mut scratch.floor_order,
            &mut scratch.outcomes[g],
            &mut scratch.union,
        );
    }
    scratch.union.sort_unstable();
    scratch.union.dedup();
    crate::obs::trace::stop_ctx(tf, crate::obs::trace::Stage::ToppSearch);
    HierPruneInfo::default()
}

/// The hierarchical page-level pre-prune (Double-P-style page-then-token
/// top-p; see module docs for the `p − hier_eps` mass guarantee).
///
/// Soundness of the bound: every token of a sealed page satisfies
/// `q·K ≤ Σᵢ max(qᵢ·mnᵢ, qᵢ·mxᵢ)` (the Quest bound), and the mirror
/// estimate deviates from `q·K` by at most `slack·Σ|qᵢ|`, where `slack`
/// is `max_error(block)` for the integer widths (per-element error ≤
/// half a step and K stays inside the block's [lo, hi]) and a
/// page-max-|K|-relative term for Fp16 (f16 round-off is relative, so
/// the constant `max_error` would be unsound there), so
/// `estimate ≤ quest_ub + slack·Σ|q|` — scaled by `1/√d` like the
/// logits. Unsealed-tail runs get a +∞ key and are always scored first.
#[allow(clippy::too_many_arguments)]
fn hier_prune_group(
    cfg: &PrunerConfig,
    cache: &PagedKvCache,
    seq: &SeqCache,
    kv_head: usize,
    qs: &[f32],
    group: usize,
    candidates: &[usize],
    s: f32,
    scratch: &mut AttnScratch,
) -> HierPruneInfo {
    let d = qs.len() / group;
    let n = candidates.len();
    let ps = cache.cfg.page_size;
    let sealed = sealed_limit(seq, ps);
    let eps = f64::from(cfg.hier_eps.clamp(0.0, 0.5));
    let hier = &mut scratch.hier;
    // Span over phases (1)-(4): segmentation, bounds, ordering, and the
    // early-stopped scoring loop (the hier replacement for Spgemv).
    let th = crate::obs::trace::timer();
    // --- (1) segment candidates into per-page runs (the tiler's own
    //         run definition — boundaries coincide by construction) -----
    hier.runs.clear();
    {
        let mut i = 0;
        while i < n {
            let j = run_end(candidates, i, sealed, ps);
            hier.runs.push(RunInfo { start: i, end: j, key: f32::INFINITY });
            i = j;
        }
    }
    let nruns = hier.runs.len();
    // --- (2) per-(run × head) scaled upper bounds ----------------------
    hier.qabs.clear();
    hier.qabs.extend(
        (0..group).map(|g| qs[g * d..(g + 1) * d].iter().map(|x| x.abs()).sum::<f32>()),
    );
    hier.bounds.clear();
    hier.bounds.resize(nruns * group, f32::INFINITY);
    for (ri, run) in hier.runs.iter_mut().enumerate() {
        let t0 = candidates[run.start];
        if t0 >= sealed {
            continue; // unsealed tail: key stays +∞, scored first
        }
        let page = seq.pages[t0 / ps];
        let (mn, mx) = cache.minmax_at(page, kv_head);
        let block = cache.mirror_at(page, kv_head).expect("sealed page missing mirror");
        let slack = if block.bits == QuantBits::Fp16 {
            // f16 round-off is *relative* (half-ulp ≈ |x|·2⁻¹¹), so the
            // integer widths' constant `max_error` is not a sound
            // per-element bound here — derive it from the page's max |K|
            // instead (2⁻¹⁰ leaves a 2× margin over the half-ulp).
            let mut maxabs = 0.0f32;
            for i in 0..d {
                maxabs = maxabs.max(mn[i].abs()).max(mx[i].abs());
            }
            maxabs * (1.0 / 1024.0)
        } else {
            // Asymmetric int quant: per-element error ≤ scale/2 and the
            // dequantized value stays inside the block's [lo, hi].
            quant::max_error(block)
        };
        let mut key = f32::NEG_INFINITY;
        for g in 0..group {
            let q = &qs[g * d..(g + 1) * d];
            let mut ub = 0.0f32;
            for i in 0..d {
                ub += (q[i] * mn[i]).max(q[i] * mx[i]);
            }
            let b = s * (ub + slack * hier.qabs[g]);
            hier.bounds[ri * group + g] = b;
            key = key.max(b);
        }
        run.key = key;
    }
    // --- (3) visit order: descending bound, ties by run index ----------
    hier.order.clear();
    hier.order.extend(0..nruns);
    {
        let runs = &hier.runs;
        hier.order.sort_unstable_by(|&a, &b| {
            runs[b]
                .key
                .partial_cmp(&runs[a].key)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
    }
    // --- (3b) per-head suffix sums of the remaining-mass bound ---------
    // The bounds are fixed after ordering, so precompute, for every
    // visit position, Σ_{not yet visited} len·exp(bound − bmax) per
    // head (shifted by the max finite bound so the sums cannot
    // overflow). Each stop check below is then O(group). Runs with a
    // +∞ key (unsealed tails, which sort to the front) are excluded:
    // while any of them remains unvisited no stop is allowed anyway.
    let inf_runs = hier
        .order
        .iter()
        .take_while(|&&r| hier.runs[r].key == f32::INFINITY)
        .count();
    hier.bmax.clear();
    hier.bmax.resize(group, f32::NEG_INFINITY);
    for (ri, run) in hier.runs.iter().enumerate() {
        if run.key == f32::INFINITY {
            continue;
        }
        for g in 0..group {
            let b = hier.bounds[ri * group + g];
            if b > hier.bmax[g] {
                hier.bmax[g] = b;
            }
        }
    }
    hier.suffix.clear();
    hier.suffix.resize((nruns + 1) * group, 0.0);
    for oi in (inf_runs..nruns).rev() {
        let rj = hier.order[oi];
        let run = hier.runs[rj];
        let len = (run.end - run.start) as f64;
        for g in 0..group {
            let shifted = f64::from(hier.bounds[rj * group + g] - hier.bmax[g]).exp();
            hier.suffix[oi * group + g] = hier.suffix[(oi + 1) * group + g] + len * shifted;
        }
    }
    // --- (4) score runs until the remainder provably cannot matter -----
    hier.scored.clear();
    hier.scored.resize(n, false);
    hier.m.clear();
    hier.m.resize(group, f64::NEG_INFINITY);
    hier.s.clear();
    hier.s.resize(group, 0.0);
    scratch.group_scores.resize(group * n, 0.0);
    // Per-head qsums once per prune call (the per-run scoring below
    // trusts them instead of recomputing the group × d reductions).
    scratch.spgemv.qsums.clear();
    scratch
        .spgemv
        .qsums
        .extend((0..group).map(|g| qs[g * d..(g + 1) * d].iter().sum::<f32>()));
    let mut scored_count = 0usize;
    let mut skipped = 0u32;
    for (oi, &ri) in hier.order.iter().enumerate() {
        if scored_count >= cfg.min_keep.max(1) && oi >= inf_runs {
            // Stop rule: for every head, the unscored runs' maximum
            // possible softmax mass fraction R/(S+R) must be ≤ eps,
            // i.e. R·(1−eps) ≤ eps·S, with
            // R = Σ_remaining count·exp(ub−M) read off the suffix sums.
            let mut stop = true;
            for g in 0..group {
                let sg = hier.s[g];
                if sg <= 0.0 {
                    stop = false;
                    break;
                }
                let rem =
                    hier.suffix[oi * group + g] * (f64::from(hier.bmax[g]) - hier.m[g]).exp();
                if rem * (1.0 - eps) > eps * sg {
                    stop = false;
                    break;
                }
            }
            if stop {
                skipped = (nruns - oi) as u32;
                break;
            }
        }
        let run = hier.runs[ri];
        let len = run.end - run.start;
        hier.run_out.resize(group * len, 0.0);
        // Per-run page-tiled scoring: bit-identical per-row values to a
        // whole-list call (rows are scored independently and the run
        // boundaries coincide with the tiler's; qsums pre-filled above).
        estimate_scores_group_with_qsums(
            cache,
            seq,
            kv_head,
            qs,
            group,
            &candidates[run.start..run.end],
            &mut hier.run_out,
            &mut scratch.spgemv,
        );
        for g in 0..group {
            for r in 0..len {
                let raw = hier.run_out[g * len + r];
                scratch.group_scores[g * n + run.start + r] = raw;
                let logit = f64::from(raw * s);
                if logit > hier.m[g] {
                    if hier.m[g].is_finite() {
                        hier.s[g] *= (hier.m[g] - logit).exp();
                    }
                    hier.m[g] = logit;
                }
                hier.s[g] += (logit - hier.m[g]).exp();
            }
        }
        for pos in run.start..run.end {
            hier.scored[pos] = true;
        }
        scored_count += len;
    }
    crate::obs::trace::stop_ctx(th, crate::obs::trace::Stage::HierPages);
    let tf = crate::obs::trace::timer();
    // --- (5) compact the scored subset back to candidate order ---------
    // Scores are gathered in ascending candidate order, so with nothing
    // skipped the compact arrays equal the full candidate arrays and the
    // finish below is bit-identical to the non-hier path — in that
    // common case finish directly on the full score matrix and skip the
    // gather entirely.
    if skipped == 0 {
        for g in 0..group {
            finish_head(
                &mut scratch.group_scores[g * n..(g + 1) * n],
                candidates,
                cfg,
                s,
                &mut scratch.topp,
                &mut scratch.floor_order,
                &mut scratch.outcomes[g],
                &mut scratch.union,
            );
        }
        scratch.union.sort_unstable();
        scratch.union.dedup();
        crate::obs::trace::stop_ctx(tf, crate::obs::trace::Stage::ToppSearch);
        return HierPruneInfo { pages_total: nruns as u32, pages_skipped: 0 };
    }
    hier.compact_pos.clear();
    hier.compact_cands.clear();
    for (pos, &was_scored) in hier.scored.iter().enumerate() {
        if was_scored {
            hier.compact_pos.push(pos);
            hier.compact_cands.push(candidates[pos]);
        }
    }
    let m = hier.compact_pos.len();
    hier.compact_scores.resize(group * m, 0.0);
    for g in 0..group {
        for (j, &pos) in hier.compact_pos.iter().enumerate() {
            hier.compact_scores[g * m + j] = scratch.group_scores[g * n + pos];
        }
    }
    for g in 0..group {
        finish_head(
            &mut hier.compact_scores[g * m..(g + 1) * m],
            &hier.compact_cands,
            cfg,
            s,
            &mut scratch.topp,
            &mut scratch.floor_order,
            &mut scratch.outcomes[g],
            &mut scratch.union,
        );
    }
    scratch.union.sort_unstable();
    scratch.union.dedup();
    crate::obs::trace::stop_ctx(tf, crate::obs::trace::Stage::ToppSearch);
    HierPruneInfo { pages_total: nruns as u32, pages_skipped: skipped }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::{random_cache, random_q};

    #[test]
    fn prune_keeps_subset_with_mass() {
        let (cache, seq) = random_cache(41, 1, 32, 256);
        let q = random_q(42, 32);
        let candidates: Vec<usize> = (0..256).collect();
        let mut scratch = PrunerScratch::default();
        let cfg = PrunerConfig { p: 0.9, ..Default::default() };
        let out = prune_head(&cfg, &cache, &seq, 0, &q, &candidates, &mut scratch);
        assert!(!out.kept.is_empty());
        assert!(out.kept.len() <= 256);
        assert!(out.mass >= 0.9 - 1e-3);
        assert!(out.kept.windows(2).all(|w| w[0] < w[1]), "sorted unique");
        assert!(out.kept.iter().all(|t| candidates.contains(t)));
    }

    #[test]
    fn focused_query_prunes_harder() {
        // Make a cache where one key matches q exactly: focused attention.
        let d = 32;
        let mut cache = crate::kvcache::PagedKvCache::new(crate::kvcache::CacheConfig::new(1, d, 32));
        let mut seq = crate::kvcache::SeqCache::default();
        let mut r = crate::util::rng::Rng::new(7);
        let q = random_q(8, d);
        for i in 0..256 {
            let k: Vec<f32> = if i == 100 {
                q.iter().map(|x| x * 4.0).collect() // strong match
            } else {
                (0..d).map(|_| r.normal_f32(0.0, 0.3)).collect()
            };
            cache.append(&mut seq, &k, &k).unwrap();
        }
        let candidates: Vec<usize> = (0..256).collect();
        let mut scratch = PrunerScratch::default();
        let cfg = PrunerConfig { p: 0.9, ..Default::default() };
        let out = prune_head(&cfg, &cache, &seq, 0, &q, &candidates, &mut scratch);
        assert!(out.kept.contains(&100), "must keep the matching token");
        assert!(out.kept.len() <= 16, "focused head should prune hard: {}", out.kept.len());
    }

    #[test]
    fn min_keep_floor() {
        let (cache, seq) = random_cache(43, 1, 16, 64);
        let q = random_q(44, 16);
        let candidates: Vec<usize> = (0..64).collect();
        let mut scratch = PrunerScratch::default();
        let cfg = PrunerConfig { p: 0.0001, min_keep: 8, ..Default::default() };
        let out = prune_head(&cfg, &cache, &seq, 0, &q, &candidates, &mut scratch);
        assert!(out.kept.len() >= 8);
    }

    #[test]
    fn floored_mass_recomputed_over_kept_set() {
        // With p≈0 the raw top-p set is a single token; the min_keep floor
        // widens it to 8, and the reported mass must cover all 8 (strictly
        // more than the single-token mass — softmax weights are positive).
        let (cache, seq) = random_cache(43, 1, 16, 64);
        let q = random_q(44, 16);
        let candidates: Vec<usize> = (0..64).collect();
        let mut scratch = PrunerScratch::default();
        let tiny = prune_head(
            &PrunerConfig { p: 0.0001, min_keep: 1, ..Default::default() },
            &cache, &seq, 0, &q, &candidates, &mut scratch,
        );
        let floored = prune_head(
            &PrunerConfig { p: 0.0001, min_keep: 8, ..Default::default() },
            &cache, &seq, 0, &q, &candidates, &mut scratch,
        );
        assert_eq!(floored.kept.len(), 8);
        assert!(floored.kept.windows(2).all(|w| w[0] < w[1]));
        assert!(
            floored.mass > tiny.mass,
            "floored mass {} must exceed pre-floor mass {}",
            floored.mass,
            tiny.mass
        );
        assert!(floored.mass <= 1.0 + 1e-5);
        // The group path shares the same floor helper.
        let (_, outs) = prune_group(
            &PrunerConfig { p: 0.0001, min_keep: 8, ..Default::default() },
            &cache, &seq, 0, &q, 1, &candidates, &mut scratch,
        );
        assert_eq!(outs[0].kept, floored.kept);
        assert!((outs[0].mass - floored.mass).abs() < 1e-5);
    }

    #[test]
    fn outcome_weights_align_with_kept() {
        let (cache, seq) = random_cache(41, 1, 32, 256);
        let q = random_q(42, 32);
        let candidates: Vec<usize> = (0..256).collect();
        let mut scratch = PrunerScratch::default();
        let cfg = PrunerConfig { p: 0.9, ..Default::default() };
        let out = prune_head(&cfg, &cache, &seq, 0, &q, &candidates, &mut scratch);
        assert_eq!(out.weights.len(), out.kept.len());
        let sum: f32 = out.weights.iter().sum();
        assert!((sum - out.mass).abs() < 1e-4, "weights sum {sum} vs mass {}", out.mass);
        assert!(out.weights.iter().all(|w| *w > 0.0));
        // The floored path must stay aligned too.
        let floored = prune_head(
            &PrunerConfig { p: 0.0001, min_keep: 8, ..Default::default() },
            &cache, &seq, 0, &q, &candidates, &mut scratch,
        );
        assert_eq!(floored.weights.len(), floored.kept.len());
        let fsum: f32 = floored.weights.iter().sum();
        assert!((fsum - floored.mass).abs() < 1e-4);
        // Short-circuit path: nothing was scored, so weights are empty.
        let few: Vec<usize> = (0..3).collect();
        let out2 = prune_head(&cfg, &cache, &seq, 0, &q, &few, &mut scratch);
        assert!(out2.weights.is_empty());
        assert_eq!(out2.kept, few);
    }

    #[test]
    fn group_union_covers_heads() {
        let (cache, seq) = random_cache(45, 1, 16, 128);
        let group = 4;
        let mut qs = Vec::new();
        for g in 0..group {
            qs.extend(random_q(50 + g as u64, 16));
        }
        let candidates: Vec<usize> = (0..128).collect();
        let mut scratch = PrunerScratch::default();
        let cfg = PrunerConfig { p: 0.8, ..Default::default() };
        let (union, outs) = prune_group(&cfg, &cache, &seq, 0, &qs, group, &candidates, &mut scratch);
        assert_eq!(outs.len(), group);
        for o in &outs {
            for t in &o.kept {
                assert!(union.binary_search(t).is_ok(), "union must contain every head's keeps");
            }
        }
        assert!(union.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn higher_p_keeps_more() {
        let (cache, seq) = random_cache(47, 1, 32, 512);
        let q = random_q(48, 32);
        let candidates: Vec<usize> = (0..512).collect();
        let mut scratch = PrunerScratch::default();
        let lo = prune_head(
            &PrunerConfig { p: 0.5, ..Default::default() },
            &cache, &seq, 0, &q, &candidates, &mut scratch,
        );
        let hi = prune_head(
            &PrunerConfig { p: 0.99, ..Default::default() },
            &cache, &seq, 0, &q, &candidates, &mut scratch,
        );
        assert!(hi.kept.len() >= lo.kept.len());
    }

    #[test]
    fn into_path_reuses_scratch_bit_exact() {
        // A dirty, repeatedly-reused arena must be invisible: the _into
        // path's union/outcomes match a fresh-scratch wrapper call bit
        // for bit, across candidate shapes and group sizes.
        let (cache, seq) = random_cache(61, 1, 32, 320);
        let cfg = PrunerConfig { p: 0.9, ..Default::default() };
        let mut dirty = PrunerScratch::default();
        for (seed, group, ncand) in [(1u64, 1usize, 320usize), (2, 4, 320), (3, 4, 77), (4, 2, 3)] {
            let mut qs = Vec::new();
            for g in 0..group {
                qs.extend(random_q(seed * 10 + g as u64, 32));
            }
            let candidates: Vec<usize> = (0..320).step_by(320 / ncand.max(1)).take(ncand).collect();
            let mut fresh = PrunerScratch::default();
            let (want_union, want_outs) =
                prune_group(&cfg, &cache, &seq, 0, &qs, group, &candidates, &mut fresh);
            prune_group_into(&cfg, &cache, &seq, 0, &qs, group, &candidates, &mut dirty);
            assert_eq!(want_union, dirty.union);
            assert_eq!(want_outs.len(), dirty.outcomes.len());
            for (a, b) in want_outs.iter().zip(&dirty.outcomes) {
                assert_eq!(a.kept, b.kept);
                assert_eq!(a.mass.to_bits(), b.mass.to_bits());
                assert_eq!(
                    a.weights.iter().map(|w| w.to_bits()).collect::<Vec<_>>(),
                    b.weights.iter().map(|w| w.to_bits()).collect::<Vec<_>>()
                );
                assert_eq!(a.iters, b.iters);
            }
        }
    }

    #[test]
    fn hier_unskipped_is_bit_exact_with_default() {
        // hier_eps = 0 makes the stop rule unsatisfiable (exp > 0), so
        // every page is scored — and because scores are scattered back to
        // candidate order before the softmax, the result must be
        // bit-identical to the non-hier path.
        let (cache, seq) = random_cache(71, 1, 32, 256);
        let group = 2;
        let mut qs = Vec::new();
        for g in 0..group {
            qs.extend(random_q(80 + g as u64, 32));
        }
        let candidates: Vec<usize> = (0..256).collect();
        let mut s1 = PrunerScratch::default();
        let mut s2 = PrunerScratch::default();
        let base = PrunerConfig { p: 0.9, ..Default::default() };
        let hier = PrunerConfig { hier_pages: true, hier_eps: 0.0, ..base };
        prune_group_into(&base, &cache, &seq, 0, &qs, group, &candidates, &mut s1);
        let info = prune_group_into(&hier, &cache, &seq, 0, &qs, group, &candidates, &mut s2);
        assert_eq!(info.pages_skipped, 0, "eps=0 must score every page");
        assert_eq!(info.pages_total, 16);
        assert_eq!(s1.union, s2.union);
        for (a, b) in s1.outcomes.iter().zip(&s2.outcomes) {
            assert_eq!(a.kept, b.kept);
            assert_eq!(a.mass.to_bits(), b.mass.to_bits());
        }
    }

    #[test]
    fn hier_skips_pages_on_peaked_heads_and_keeps_mass() {
        // A strongly-matching key concentrates the softmax on one page;
        // the hier pre-prune must skip most of the cold pages while the
        // kept set still captures ≥ p − hier_eps of the *full-candidate*
        // estimated mass.
        let d = 32;
        let mut cache = crate::kvcache::PagedKvCache::new(crate::kvcache::CacheConfig::new(1, d, 40));
        let mut seq = crate::kvcache::SeqCache::default();
        let mut r = crate::util::rng::Rng::new(9);
        let q = random_q(18, d);
        for i in 0..512 {
            let k: Vec<f32> = if i == 200 {
                q.iter().map(|x| x * 5.0).collect()
            } else {
                (0..d).map(|_| r.normal_f32(0.0, 0.2)).collect()
            };
            cache.append(&mut seq, &k, &k).unwrap();
        }
        let candidates: Vec<usize> = (0..512).collect();
        let p = 0.9f32;
        let eps = 0.02f32;
        let cfg = PrunerConfig { p, hier_pages: true, hier_eps: eps, ..Default::default() };
        let mut scratch = PrunerScratch::default();
        let info = prune_group_into(&cfg, &cache, &seq, 0, &q, 1, &candidates, &mut scratch);
        assert!(info.pages_total == 32, "512 tokens = 32 page runs");
        assert!(
            info.pages_skipped > 8,
            "peaked head should skip many cold pages, skipped {}",
            info.pages_skipped
        );
        let kept = scratch.outcomes[0].kept.clone();
        assert!(kept.contains(&200), "the hot token must survive");
        // Full-candidate estimated softmax (row-major reference).
        let mut est = vec![0.0; candidates.len()];
        crate::attention::spgemv::estimate_scores_rowmajor(
            &cache, &seq, 0, &q, &candidates, &mut est,
        );
        let s = crate::attention::scale(d);
        for x in est.iter_mut() {
            *x *= s;
        }
        crate::tensor::softmax_inplace(&mut est);
        let full_mass: f32 = kept.iter().map(|&t| est[t]).sum();
        assert!(
            full_mass >= p - eps - 1e-3,
            "captured mass {} < p − δ = {}",
            full_mass,
            p - eps
        );
    }
}
