//! Top-p selection over normalized attention weights.
//!
//! Two implementations:
//! * [`topp_sort`] — the oracle: sort descending, take the minimal prefix
//!   whose sum ≥ p (Definition 3.3). O(n log n), sequential.
//! * [`topp_binary_search`] — Algorithm 1 from the paper: binary search on
//!   the weight threshold with fused elementwise passes; parallel-friendly
//!   (each pass is a vectorizable map-reduce, no data-dependent order),
//!   which is why the GPU kernel uses it. Returns a superset-or-equal of
//!   the sort oracle's mass with |I| within one threshold-tie of minimal.

/// Result of a top-p selection.
#[derive(Clone, Debug)]
pub struct ToppResult {
    /// Selected indices (ascending).
    pub indices: Vec<usize>,
    /// Sum of selected weights.
    pub mass: f32,
    /// Final threshold: weights >= this were kept.
    pub threshold: f32,
    /// Binary-search iterations used (0 for the sort oracle).
    pub iters: usize,
}

/// Scalar half of a scratch-based top-p result; the selected indices land
/// in [`ToppScratch::indices`].
#[derive(Clone, Copy, Debug)]
pub struct ToppStats {
    pub mass: f32,
    pub threshold: f32,
    pub iters: usize,
}

/// Reusable buffers for [`topp_binary_search_into`] (part of the
/// per-worker `AttnScratch` arena): the shrinking active set, the
/// selected-index output, and the fp-drift fallback staging. Capacity
/// only grows, so steady-state calls are allocation-free.
#[derive(Default)]
pub struct ToppScratch {
    active: Vec<f32>,
    /// Selected indices (ascending) of the most recent search.
    pub indices: Vec<usize>,
    rest: Vec<usize>,
}

/// Oracle top-p: minimal prefix of the descending sort with mass ≥ p.
pub fn topp_sort(w: &[f32], p: f32) -> ToppResult {
    let mut order: Vec<usize> = (0..w.len()).collect();
    order.sort_by(|&a, &b| w[b].partial_cmp(&w[a]).unwrap_or(std::cmp::Ordering::Equal));
    let mut mass = 0.0f32;
    let mut kept = Vec::new();
    let mut threshold = 0.0f32;
    for &i in &order {
        kept.push(i);
        mass += w[i];
        threshold = w[i];
        if mass >= p {
            break;
        }
    }
    kept.sort_unstable();
    ToppResult { indices: kept, mass, threshold, iters: 0 }
}

/// Algorithm 1: top-p via binary search on the threshold.
///
/// Invariant maintained: `mass(w >= l) >= p` (l starts at 0 where mass = 1
/// for normalized w) and `mass(w >= r) < p` — shrink until no weight lies
/// strictly between `l` and `r`, then keep `w >= l`. Each iteration is a
/// single fused pass (sum-above, plus the bracket-gap extrema), exactly
/// the `where/sum/max` fusion the paper tensorizes on GPU.
pub fn topp_binary_search(w: &[f32], p: f32, eps: f32) -> ToppResult {
    let mut s = ToppScratch::default();
    let st = topp_binary_search_into(w, p, eps, &mut s);
    ToppResult { indices: s.indices, mass: st.mass, threshold: st.threshold, iters: st.iters }
}

/// Allocation-free core of [`topp_binary_search`]: identical algorithm,
/// with the active set, selected indices, and fallback staging drawn from
/// the caller's [`ToppScratch`]. The selected indices (ascending) are
/// left in `scratch.indices`.
pub fn topp_binary_search_into(w: &[f32], p: f32, eps: f32, s: &mut ToppScratch) -> ToppStats {
    s.indices.clear();
    if w.is_empty() {
        return ToppStats { mass: 0.0, threshold: 0.0, iters: 0 };
    }
    let wmax = w.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut l = 0.0f32;
    let mut r = wmax;
    let mut iters = 0;
    // Active-set bisection (§Perf): the bracket [l, r] only shrinks, so
    // any weight >= r is kept for sure (its mass is banked) and any
    // weight < l is dropped for sure — both leave the active set, which
    // shrinks geometrically. Each pass is a branch-light scan, the same
    // fused `where/sum` the GPU kernel tensorizes, but over ever fewer
    // elements.
    s.active.clear();
    s.active.extend_from_slice(w);
    let active = &mut s.active;
    let mut banked = 0.0f32; // mass of weights proven >= threshold
    while iters < 32 && !active.is_empty() {
        let m = 0.5 * (l + r);
        let mut mass_above = banked;
        for &x in active.iter() {
            if x >= m {
                mass_above += x;
            }
        }
        iters += 1;
        if mass_above >= p {
            l = m;
        } else {
            r = m;
        }
        // Compact: bank definite keeps, drop definite rejects.
        let mut gap_min = f32::INFINITY;
        let mut gap_max = f32::NEG_INFINITY;
        active.retain(|&x| {
            if x >= r {
                banked += x;
                false
            } else if x < l {
                false
            } else {
                gap_min = gap_min.min(x);
                gap_max = gap_max.max(x);
                true
            }
        });
        // Converged when the remaining bracket contains (almost) no
        // distinct weight values.
        if gap_max - gap_min <= eps || r - l <= eps * 1e-2 {
            break;
        }
    }
    let mut mass = 0.0f32;
    for (i, &x) in w.iter().enumerate() {
        if x >= l {
            s.indices.push(i);
            mass += x;
        }
    }
    // Guard: if fp drift left us below p (possible when eps is loose),
    // fall back to widening by the sort oracle on the remainder.
    if mass < p && s.indices.len() < w.len() {
        s.rest.clear();
        s.rest.extend((0..w.len()).filter(|i| w[*i] < l));
        // (weight desc, idx asc) total order via an unstable sort: the
        // identical sequence the historical stable descending sort gave
        // (`rest` is built in ascending index order), minus the stable
        // sort's temp-buffer allocation — this fallback sits inside the
        // hot path's zero-allocation contract.
        s.rest.sort_unstable_by(|&a, &b| {
            w[b].partial_cmp(&w[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
        });
        for &i in &s.rest {
            s.indices.push(i);
            mass += w[i];
            if mass >= p {
                break;
            }
        }
        s.indices.sort_unstable();
    }
    ToppStats { mass, threshold: l, iters }
}

/// Budget needed by oracle top-p (the |I| of Definition 3.3) — used by
/// the budget-dynamism analyses (Fig. 4 / Fig. 11).
pub fn oracle_budget(w: &[f32], p: f32) -> usize {
    topp_sort(w, p).indices.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::softmax_inplace;
    use crate::util::rng::Rng;

    fn softmaxed(seed: u64, n: usize, sharp: f32) -> Vec<f32> {
        let mut r = Rng::new(seed);
        let mut w: Vec<f32> = (0..n).map(|_| r.normal_f32(0.0, sharp)).collect();
        softmax_inplace(&mut w);
        w
    }

    #[test]
    fn sort_oracle_minimal() {
        let w = vec![0.5, 0.3, 0.1, 0.05, 0.05];
        let r = topp_sort(&w, 0.75);
        assert_eq!(r.indices, vec![0, 1]);
        assert!((r.mass - 0.8).abs() < 1e-6);
        let r = topp_sort(&w, 0.85);
        assert_eq!(r.indices, vec![0, 1, 2]);
    }

    #[test]
    fn binary_search_reaches_mass() {
        for (seed, sharp) in [(1u64, 0.5f32), (2, 2.0), (3, 6.0)] {
            for n in [16usize, 100, 1000] {
                let w = softmaxed(seed, n, sharp);
                for p in [0.5f32, 0.8, 0.9, 0.95, 0.99] {
                    let r = topp_binary_search(&w, p, 1e-6);
                    assert!(r.mass >= p - 1e-4, "n={n} p={p} mass={}", r.mass);
                }
            }
        }
    }

    #[test]
    fn binary_search_near_minimal() {
        for seed in 0..10u64 {
            let w = softmaxed(seed, 512, 3.0);
            let p = 0.9;
            let oracle = topp_sort(&w, p);
            let bs = topp_binary_search(&w, p, 1e-7);
            // Binary search may keep threshold-ties; allow small slack.
            assert!(
                bs.indices.len() <= oracle.indices.len() + 4,
                "seed={seed} bs={} oracle={}",
                bs.indices.len(),
                oracle.indices.len()
            );
        }
    }

    #[test]
    fn focused_needs_fewer_than_diffuse() {
        // The core top-p claim (Fig. 3/4): a peaked distribution needs far
        // fewer tokens than a flat one at the same p.
        let focused = softmaxed(5, 1024, 8.0);
        let diffuse = softmaxed(6, 1024, 0.3);
        let bf = oracle_budget(&focused, 0.9);
        let bd = oracle_budget(&diffuse, 0.9);
        assert!(bf * 4 < bd, "focused {bf} vs diffuse {bd}");
    }

    #[test]
    fn uniform_distribution_selects_fraction_p() {
        let n = 1000;
        let w = vec![1.0 / n as f32; n];
        let r = topp_binary_search(&w, 0.9, 1e-9);
        // All weights equal: threshold keeps all (ties) — mass = 1.
        assert!(r.mass >= 0.9);
        let o = topp_sort(&w, 0.9);
        // fp accumulation of 1000 equal weights may land one off 900.
        assert!((o.indices.len() as i64 - 900).abs() <= 2, "{}", o.indices.len());
    }

    #[test]
    fn single_spike() {
        let mut w = vec![0.0001f32; 100];
        w[42] = 1.0;
        let total: f32 = w.iter().sum();
        for x in w.iter_mut() {
            *x /= total;
        }
        let r = topp_binary_search(&w, 0.9, 1e-8);
        assert_eq!(r.indices, vec![42]);
    }

    #[test]
    fn empty_and_p_zero() {
        let r = topp_binary_search(&[], 0.9, 1e-6);
        assert!(r.indices.is_empty());
        let w = vec![0.25f32; 4];
        let r = topp_binary_search(&w, 0.0, 1e-6);
        assert!(r.mass >= 0.0);
    }

    #[test]
    fn scratch_reuse_matches_fresh() {
        // A dirty, repeatedly-reused scratch must be invisible: identical
        // indices, bit-identical mass/threshold, same iteration count.
        let mut s = ToppScratch::default();
        for seed in 0..6u64 {
            for (n, p) in [(257usize, 0.9f32), (16, 0.5), (1000, 0.99)] {
                let w = softmaxed(seed, n, 2.5);
                let fresh = topp_binary_search(&w, p, 1e-6);
                let st = topp_binary_search_into(&w, p, 1e-6, &mut s);
                assert_eq!(fresh.indices, s.indices);
                assert_eq!(fresh.mass.to_bits(), st.mass.to_bits());
                assert_eq!(fresh.threshold.to_bits(), st.threshold.to_bits());
                assert_eq!(fresh.iters, st.iters);
            }
        }
    }

    #[test]
    fn iters_bounded() {
        let w = softmaxed(9, 4096, 2.0);
        let r = topp_binary_search(&w, 0.95, 1e-6);
        assert!(r.iters <= 32);
    }
}
