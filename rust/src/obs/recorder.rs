//! Flight recorder: a bounded ring of the last N per-step summaries
//! (timings, governor directive, budgets, anomalies), dumped to stderr
//! as JSON-lines on panic or SLO breach and to the server client on
//! `{"cmd":"dump"}` — the postmortem tool for stuck or degraded runs.
//!
//! The ring is a pre-sized `Vec<StepRecord>` behind a `Mutex`: records
//! are `Copy`, pushes after warm-up overwrite in place, so recording is
//! allocation-free and costs one uncontended lock per scheduler step
//! (the scheduler is the only writer; dumps are the only other reader).

use crate::util::json::{self, Json};
use std::sync::{Mutex, Once, OnceLock};

/// Most severe thing that happened in a step (priority-ordered).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Anomaly {
    None = 0,
    /// A decode item was preempted back to the queue this step.
    Preempt = 1,
    /// An admission was rejected (prompt cannot ever fit).
    Reject = 2,
    /// Smoothed TPOT ran over the SLO breach threshold.
    SloBreach = 3,
    /// A request terminally failed to a contained fault this step (lost
    /// KV page, quarantined worker panic, non-finite logits) — the most
    /// severe outcome: service was lost, not merely degraded.
    Failed = 4,
}

impl Anomaly {
    pub fn name(self) -> &'static str {
        match self {
            Anomaly::None => "none",
            Anomaly::Preempt => "preempt",
            Anomaly::Reject => "reject",
            Anomaly::SloBreach => "slo_breach",
            Anomaly::Failed => "failed",
        }
    }
}

/// One scheduler step, summarized. `Copy` so ring pushes never allocate.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    /// Scheduler step ordinal.
    pub step: u64,
    /// Caller-supplied virtual/wall time handed to `Scheduler::step`.
    pub now: f64,
    /// Wall seconds of the whole engine step, and its decode/prefill
    /// split (from `Engine::last_step_timing`).
    pub step_s: f64,
    pub decode_s: f64,
    pub prefill_s: f64,
    /// Decode tokens produced this step.
    pub produced: u32,
    pub queue: u32,
    pub running: u32,
    pub prefilling: u32,
    pub free_pages: u32,
    /// Kept/candidate token deltas over this step (budget actually used).
    pub kept_delta: u64,
    pub candidates_delta: u64,
    /// Governor directive in force.
    pub p_scale: f32,
    pub budget_scale: f32,
    pub degrade: u8,
    pub anomaly: Anomaly,
}

impl StepRecord {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("step", Json::Num(self.step as f64)),
            ("now", Json::Num(self.now)),
            ("step_s", Json::Num(self.step_s)),
            ("decode_s", Json::Num(self.decode_s)),
            ("prefill_s", Json::Num(self.prefill_s)),
            ("produced", Json::Num(self.produced as f64)),
            ("queue", Json::Num(self.queue as f64)),
            ("running", Json::Num(self.running as f64)),
            ("prefilling", Json::Num(self.prefilling as f64)),
            ("free_pages", Json::Num(self.free_pages as f64)),
            ("kept_delta", Json::Num(self.kept_delta as f64)),
            ("candidates_delta", Json::Num(self.candidates_delta as f64)),
            ("p_scale", Json::Num(self.p_scale as f64)),
            ("budget_scale", Json::Num(self.budget_scale as f64)),
            ("degrade", Json::Num(self.degrade as f64)),
            ("anomaly", json::s(self.anomaly.name())),
        ])
    }
}

/// Bounded ring of the last `cap` step records.
pub struct FlightRecorder {
    ring: Vec<StepRecord>,
    /// Ring bound (`Vec::capacity` is only a lower bound, so keep our own).
    cap: usize,
    /// Total records ever pushed; `% cap` is the overwrite slot.
    head: u64,
}

const DEFAULT_CAP: usize = 256;

impl FlightRecorder {
    fn new(cap: usize) -> FlightRecorder {
        let cap = cap.max(1);
        FlightRecorder { ring: Vec::with_capacity(cap), cap, head: 0 }
    }

    fn push(&mut self, r: StepRecord) {
        let slot = (self.head % self.cap as u64) as usize;
        if self.ring.len() < self.cap {
            self.ring.push(r);
        } else {
            self.ring[slot] = r;
        }
        self.head += 1;
    }

    /// Records in chronological order, oldest kept first.
    fn ordered(&self) -> Vec<StepRecord> {
        let mut out = Vec::with_capacity(self.ring.len());
        if self.head <= self.cap as u64 {
            out.extend_from_slice(&self.ring);
        } else {
            let split = (self.head % self.cap as u64) as usize;
            out.extend_from_slice(&self.ring[split..]);
            out.extend_from_slice(&self.ring[..split]);
        }
        out
    }
}

fn global() -> &'static Mutex<FlightRecorder> {
    static R: OnceLock<Mutex<FlightRecorder>> = OnceLock::new();
    R.get_or_init(|| {
        let cap = std::env::var("TWILIGHT_RECORDER_CAP")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(DEFAULT_CAP);
        Mutex::new(FlightRecorder::new(cap))
    })
}

/// Append a step record to the global ring.
pub fn record(r: StepRecord) {
    global().lock().unwrap_or_else(|e| e.into_inner()).push(r);
}

/// Chronological snapshot of the retained records.
pub fn snapshot() -> Vec<StepRecord> {
    global().lock().unwrap_or_else(|e| e.into_inner()).ordered()
}

/// `{"records":[…]}` — the `{"cmd":"dump"}` reply body.
pub fn to_json() -> Json {
    let records = snapshot().iter().map(|r| r.to_json()).collect();
    json::obj(vec![("records", Json::Arr(records))])
}

/// Dump the newest `max` records (0 = all retained) to stderr as
/// JSON-lines, newest last, with a one-line `reason` header.
pub fn dump_stderr(reason: &str, max: usize) {
    // try_lock: the panic hook must never deadlock against a holder
    // that panicked while recording.
    let Ok(rec) = global().try_lock() else {
        eprintln!("twilight flight-recorder: {reason} (ring busy, skipping dump)");
        return;
    };
    let all = rec.ordered();
    drop(rec);
    let skip = if max == 0 { 0 } else { all.len().saturating_sub(max) };
    eprintln!(
        "twilight flight-recorder: {reason} — last {} step record(s):",
        all.len() - skip
    );
    for r in &all[skip..] {
        eprintln!("{}", r.to_json().to_string());
    }
}

/// Install a panic hook (once) that dumps the flight recorder before
/// the default hook runs. Safe to call repeatedly.
pub fn install_panic_hook() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let default = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            dump_stderr("panic", 0);
            default(info);
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: u64) -> StepRecord {
        StepRecord {
            step,
            now: step as f64 * 0.01,
            step_s: 1e-3,
            decode_s: 8e-4,
            prefill_s: 2e-4,
            produced: 3,
            queue: 1,
            running: 3,
            prefilling: 0,
            free_pages: 100,
            kept_delta: 640,
            candidates_delta: 2048,
            p_scale: 1.0,
            budget_scale: 1.0,
            degrade: 0,
            anomaly: Anomaly::None,
        }
    }

    #[test]
    fn ring_bounds_and_orders() {
        let mut fr = FlightRecorder::new(4);
        for i in 0..10 {
            fr.push(rec(i));
        }
        let got = fr.ordered();
        assert_eq!(got.len(), 4);
        let steps: Vec<u64> = got.iter().map(|r| r.step).collect();
        assert_eq!(steps, vec![6, 7, 8, 9]);
        // The ring never grew past its bound.
        assert_eq!(fr.ring.len(), 4);
        assert_eq!(fr.cap, 4);
    }

    #[test]
    fn json_shape() {
        let j = rec(41_203).to_json();
        assert_eq!(j.get_f64("step"), Some(41_203.0));
        assert_eq!(j.get_str("anomaly"), Some("none"));
        let parsed = Json::parse(&j.to_string()).expect("record JSON round-trips");
        assert_eq!(parsed.get_f64("produced"), Some(3.0));
    }

    #[test]
    fn global_record_and_dump_shape() {
        record(rec(1));
        record(rec(2));
        let j = to_json();
        let arr = j.get("records").unwrap().as_arr().unwrap();
        assert!(arr.len() >= 2);
        dump_stderr("test", 1);
    }

    #[test]
    fn anomaly_priority_order() {
        assert!(Anomaly::Failed > Anomaly::SloBreach);
        assert!(Anomaly::SloBreach > Anomaly::Reject);
        assert!(Anomaly::Reject > Anomaly::Preempt);
        assert!(Anomaly::Preempt > Anomaly::None);
        assert_eq!(Anomaly::Failed.name(), "failed");
    }
}
