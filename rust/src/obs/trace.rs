//! Span tracing: per-thread lock-free ring buffers of begin/end spans
//! for every pipeline stage, exportable as Chrome trace-event JSON
//! (load the file in Perfetto / `chrome://tracing`).
//!
//! Design constraints (DESIGN.md §10):
//!
//! * **Bit-exact-neutral** — recording is purely observational: no span
//!   ever feeds back into scheduling, pruning, sampling, or RNG state,
//!   so the golden decode trace is identical with tracing on or off
//!   (pinned by `rust/tests/trace_obs.rs`).
//! * **Near-free when off** — every record site starts with one relaxed
//!   atomic load (`enabled()`) and returns; no clock read, no TLS touch,
//!   no allocation (pinned by `rust/tests/alloc_count.rs`).
//! * **Allocation-free per event when on** — each thread lazily creates
//!   one fixed-capacity ring (a single allocation, registered globally
//!   for export) and every subsequent event is four relaxed `AtomicU64`
//!   stores plus a release bump of the head. When the ring wraps, the
//!   oldest spans are dropped (counted, never reallocated).
//!
//! Threading model: a ring has exactly one writer — the thread that owns
//! it — so `push` needs no CAS loop. Readers (`snapshot`, the Chrome
//! exporter) take the registry lock and read `head` with `Acquire`; a
//! writer that wraps mid-snapshot can tear at most the events it is
//! overwriting, which only matters for live dumps of a still-running
//! ring (tests snapshot quiesced rings).

use std::cell::{Cell, OnceCell};
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Pipeline stages a span can be attributed to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Stage {
    /// Stage-1 token selection (Quest, Double Sparsity, …).
    Select = 0,
    /// One whole stage-2 pruner call — the umbrella over
    /// [`Stage::Spgemv`] / [`Stage::ToppSearch`] / [`Stage::HierPages`],
    /// and the span that reconciles against `EngineStats::t_prune`.
    Prune = 1,
    /// Quantized SpGEMV score estimation (non-hier pruner path).
    Spgemv = 2,
    /// Per-head softmax + top-p search + min-keep floor + union merge.
    ToppSearch = 3,
    /// Hier-pages machinery: run segmentation, per-run bounds, visit
    /// ordering, and the early-stopped per-run scoring loop.
    HierPages = 4,
    /// Stage-3 varlen sparse attention over the kept set.
    SparseAttend = 5,
    /// Dense attention (skip layers, short contexts, dense baselines).
    DenseAttend = 6,
    /// Phase-(a) prefill-chunk/decode append for one layer: norms, QKV
    /// GEMVs, RoPE, and the KV-cache appends.
    Append = 7,
    /// Final-token unembedding (`lm_head` GEMV) for the step.
    Unembed = 8,
    /// One pooled round of the attention worker pool (inline rounds —
    /// `threads == 1` or `n <= chunk` — are not pooled and not recorded).
    PoolRound = 9,
    /// One whole mixed engine step (decode items + prefill chunks).
    Step = 10,
    /// One page fault: a non-resident sealed page copied back from the
    /// slow tier (tiered offload; demand reads and prefetch tickets
    /// both record here).
    PageFault = 11,
    /// Sparse-prefill attention: the bound-guided page-skipping kernel
    /// over a chunk item's query span (`attention::prefill`,
    /// DESIGN.md §13). Reconciles against `EngineStats::t_sprefill`.
    SparsePrefill = 12,
}

/// Number of [`Stage`] variants (array-indexing helper).
pub const N_STAGES: usize = 13;

impl Stage {
    pub const ALL: [Stage; N_STAGES] = [
        Stage::Select,
        Stage::Prune,
        Stage::Spgemv,
        Stage::ToppSearch,
        Stage::HierPages,
        Stage::SparseAttend,
        Stage::DenseAttend,
        Stage::Append,
        Stage::Unembed,
        Stage::PoolRound,
        Stage::Step,
        Stage::PageFault,
        Stage::SparsePrefill,
    ];

    /// Stable lowercase name (Chrome event name / Prometheus-ish label).
    pub fn name(self) -> &'static str {
        match self {
            Stage::Select => "select",
            Stage::Prune => "prune",
            Stage::Spgemv => "spgemv",
            Stage::ToppSearch => "topp_search",
            Stage::HierPages => "hier_pages",
            Stage::SparseAttend => "sparse_attend",
            Stage::DenseAttend => "dense_attend",
            Stage::Append => "append",
            Stage::Unembed => "unembed",
            Stage::PoolRound => "pool_round",
            Stage::Step => "step",
            Stage::PageFault => "page_fault",
            Stage::SparsePrefill => "sparse_prefill",
        }
    }

    fn from_u8(b: u8) -> Option<Stage> {
        Stage::ALL.get(b as usize).copied()
    }
}

/// Span tags; `u32::MAX` / `u16::MAX` mean "unset" (omitted on export).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tags {
    /// Engine step ordinal (every `run_batch` call, chunk-only included).
    pub step: u32,
    /// Batch-item index within the step (not the sequence id).
    pub seq: u32,
    pub layer: u16,
    pub kv_head: u16,
}

impl Tags {
    pub const NONE: Tags =
        Tags { step: u32::MAX, seq: u32::MAX, layer: u16::MAX, kv_head: u16::MAX };
}

/// One decoded span.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub stage: Stage,
    /// Nanoseconds since the process-wide trace epoch.
    pub begin_ns: u64,
    pub dur_ns: u64,
    pub tags: Tags,
}

/// Fixed-capacity single-writer ring of packed span events
/// (4 × `u64` per event: begin, duration, stage+layer+head, seq+step).
pub struct SpanRing {
    label: String,
    slots: Box<[[AtomicU64; 4]]>,
    /// Total events ever pushed (monotonic; `% capacity` is the slot).
    head: AtomicUsize,
}

impl SpanRing {
    fn new(capacity: usize, label: String) -> SpanRing {
        let slots = (0..capacity.max(1))
            .map(|_| [const { AtomicU64::new(0) }; 4])
            .collect::<Vec<_>>()
            .into_boxed_slice();
        SpanRing { label, slots, head: AtomicUsize::new(0) }
    }

    /// Single-writer append (only the owning thread calls this).
    fn push(&self, stage: Stage, begin_ns: u64, dur_ns: u64, tags: Tags) {
        let head = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[head % self.slots.len()];
        let meta = stage as u64 | (tags.layer as u64) << 8 | (tags.kv_head as u64) << 24;
        let ids = tags.seq as u64 | (tags.step as u64) << 32;
        slot[0].store(begin_ns, Ordering::Relaxed);
        slot[1].store(dur_ns, Ordering::Relaxed);
        slot[2].store(meta, Ordering::Relaxed);
        slot[3].store(ids, Ordering::Relaxed);
        self.head.store(head + 1, Ordering::Release);
    }

    fn decode(&self) -> (Vec<Span>, u64) {
        let head = self.head.load(Ordering::Acquire);
        let cap = self.slots.len();
        let kept = head.min(cap);
        let mut spans = Vec::with_capacity(kept);
        for i in (head - kept)..head {
            let slot = &self.slots[i % cap];
            let meta = slot[2].load(Ordering::Relaxed);
            let ids = slot[3].load(Ordering::Relaxed);
            let Some(stage) = Stage::from_u8((meta & 0xFF) as u8) else { continue };
            spans.push(Span {
                stage,
                begin_ns: slot[0].load(Ordering::Relaxed),
                dur_ns: slot[1].load(Ordering::Relaxed),
                tags: Tags {
                    step: (ids >> 32) as u32,
                    seq: (ids & 0xFFFF_FFFF) as u32,
                    layer: ((meta >> 8) & 0xFFFF) as u16,
                    kv_head: ((meta >> 24) & 0xFFFF) as u16,
                },
            });
        }
        (spans, (head - kept) as u64)
    }
}

/// The spans of one thread's ring, decoded for export/tests.
pub struct ThreadSpans {
    /// Thread label (the worker's thread name, e.g. `twilight-attn-0`).
    pub label: String,
    /// Registry index — the Chrome `tid`.
    pub tid: usize,
    /// Chronological (the ring drops oldest-first on wrap).
    pub spans: Vec<Span>,
    /// Events lost to ring wrap on this thread.
    pub dropped: u64,
}

// --- global state --------------------------------------------------------

/// Tri-state: 0 = uninitialized (read `TWILIGHT_TRACE` lazily),
/// 1 = off, 2 = on. Hot paths pay exactly one relaxed load.
static ENABLED: AtomicU8 = AtomicU8::new(0);

fn registry() -> &'static Mutex<Vec<Arc<SpanRing>>> {
    static R: OnceLock<Mutex<Vec<Arc<SpanRing>>>> = OnceLock::new();
    R.get_or_init(|| Mutex::new(Vec::new()))
}

fn epoch() -> Instant {
    static E: OnceLock<Instant> = OnceLock::new();
    *E.get_or_init(Instant::now)
}

/// Nanoseconds since the process-wide trace epoch.
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

/// Per-thread ring capacity in events (`TWILIGHT_TRACE_CAP`, read once
/// at the first ring creation). 32 Ki events ≈ 1 MiB per thread.
const DEFAULT_CAP: usize = 1 << 15;

fn ring_capacity() -> usize {
    static CAP: OnceLock<usize> = OnceLock::new();
    *CAP.get_or_init(|| {
        std::env::var("TWILIGHT_TRACE_CAP")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(DEFAULT_CAP)
            .max(1)
    })
}

#[cold]
fn init_enabled() -> bool {
    let on = std::env::var("TWILIGHT_TRACE").is_ok_and(|v| v == "1" || v == "true");
    ENABLED.store(if on { 2 } else { 1 }, Ordering::Relaxed);
    on
}

/// Is span tracing on? First call resolves `TWILIGHT_TRACE`; after that
/// this is a single relaxed atomic load.
#[inline]
pub fn enabled() -> bool {
    match ENABLED.load(Ordering::Relaxed) {
        2 => true,
        1 => false,
        _ => init_enabled(),
    }
}

/// Toggle tracing at runtime (`--trace`, tests, benches).
pub fn set_enabled(on: bool) {
    ENABLED.store(if on { 2 } else { 1 }, Ordering::Relaxed);
}

thread_local! {
    static LOCAL_RING: OnceCell<Arc<SpanRing>> = const { OnceCell::new() };
    static CTX: Cell<Tags> = const { Cell::new(Tags::NONE) };
}

fn with_ring(f: impl FnOnce(&SpanRing)) {
    LOCAL_RING.with(|cell| {
        let ring = cell.get_or_init(|| {
            let label = std::thread::current().name().unwrap_or("main").to_string();
            let ring = Arc::new(SpanRing::new(ring_capacity(), label));
            registry().lock().unwrap_or_else(|e| e.into_inner()).push(Arc::clone(&ring));
            ring
        });
        f(ring);
    });
}

fn push_event(stage: Stage, begin_ns: u64, dur_ns: u64, tags: Tags) {
    with_ring(|r| r.push(stage, begin_ns, dur_ns, tags));
}

// --- record API ----------------------------------------------------------

/// Set this thread's span context; subsequent [`record_ctx`] calls (on
/// this thread, including from the pruner and the pool) inherit it.
#[inline]
pub fn set_ctx(tags: Tags) {
    if enabled() {
        CTX.with(|c| c.set(tags));
    }
}

/// This thread's current span context ([`Tags::NONE`] when unset).
#[inline]
pub fn ctx() -> Tags {
    CTX.with(|c| c.get())
}

/// Record a span that just ended, `dur` long (begin is reconstructed as
/// `now - dur`, so callers can reuse the `Instant::elapsed()` they
/// already measured for `EngineStats` — span and stat durations are the
/// same measurement by construction).
#[inline]
pub fn record(stage: Stage, dur: Duration, tags: Tags) {
    if !enabled() {
        return;
    }
    let end = now_ns();
    let dur_ns = dur.as_nanos().min(u64::MAX as u128) as u64;
    push_event(stage, end.saturating_sub(dur_ns), dur_ns, tags);
}

/// [`record`] with this thread's [`ctx`] tags.
#[inline]
pub fn record_ctx(stage: Stage, dur: Duration) {
    if !enabled() {
        return;
    }
    let end = now_ns();
    let dur_ns = dur.as_nanos().min(u64::MAX as u128) as u64;
    push_event(stage, end.saturating_sub(dur_ns), dur_ns, ctx());
}

/// Begin-of-span marker for sites without a pre-existing `Instant`:
/// returns the current trace time (never 0) when tracing is on, 0 when
/// off. Pair with [`record_since`] / a `timer()`-style option.
#[inline]
pub fn mark() -> u64 {
    if enabled() {
        now_ns().max(1)
    } else {
        0
    }
}

/// Close the span opened by [`mark`] (no-op for a disabled-at-begin 0).
#[inline]
pub fn record_since(mark: u64, stage: Stage, tags: Tags) {
    if mark == 0 || !enabled() {
        return;
    }
    let end = now_ns();
    push_event(stage, mark, end.saturating_sub(mark), tags);
}

/// An `Option<Instant>` timer: `Some` only when tracing is on, so the
/// disabled path never reads the clock.
#[inline]
pub fn timer() -> Option<Instant> {
    if enabled() {
        Some(Instant::now())
    } else {
        None
    }
}

/// Close a [`timer`] span with this thread's [`ctx`] tags.
#[inline]
pub fn stop_ctx(t: Option<Instant>, stage: Stage) {
    if let Some(t) = t {
        record_ctx(stage, t.elapsed());
    }
}

/// Close a [`timer`] span with explicit tags.
#[inline]
pub fn stop(t: Option<Instant>, stage: Stage, tags: Tags) {
    if let Some(t) = t {
        record(stage, t.elapsed(), tags);
    }
}

// --- export --------------------------------------------------------------

/// Decode every registered ring (one entry per thread that recorded).
pub fn snapshot() -> Vec<ThreadSpans> {
    let rings: Vec<Arc<SpanRing>> =
        registry().lock().unwrap_or_else(|e| e.into_inner()).clone();
    rings
        .iter()
        .enumerate()
        .map(|(tid, r)| {
            let (spans, dropped) = r.decode();
            ThreadSpans { label: r.label.clone(), tid, spans, dropped }
        })
        .collect()
}

/// Seconds spent in each stage, summed over every ring (index by
/// `Stage as usize`). Events lost to ring wrap are not in the totals.
pub fn stage_totals() -> [f64; N_STAGES] {
    let mut totals = [0.0; N_STAGES];
    for t in snapshot() {
        for s in &t.spans {
            totals[s.stage as usize] += s.dur_ns as f64 * 1e-9;
        }
    }
    totals
}

/// Total events currently held across rings plus events lost to wrap.
pub fn event_counts() -> (u64, u64) {
    let mut held = 0;
    let mut dropped = 0;
    for t in snapshot() {
        held += t.spans.len() as u64;
        dropped += t.dropped;
    }
    (held, dropped)
}

/// Empty every ring (tests/benches; rings stay registered and sized).
pub fn reset() {
    for r in registry().lock().unwrap_or_else(|e| e.into_inner()).iter() {
        r.head.store(0, Ordering::Release);
    }
}

/// Render every ring as Chrome trace-event JSON (the `traceEvents`
/// array format Perfetto and `chrome://tracing` load directly):
/// `"X"` complete events with microsecond `ts`/`dur`, one `tid` per
/// ring, plus `thread_name` metadata events.
pub fn render_chrome() -> String {
    use std::fmt::Write;
    let threads = snapshot();
    let mut out = String::with_capacity(1 << 16);
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    let mut first = true;
    let mut sep = |out: &mut String| {
        if first {
            first = false;
        } else {
            out.push(',');
        }
    };
    for t in &threads {
        sep(&mut out);
        let name = crate::util::json::s(&t.label).to_string();
        let _ = write!(
            out,
            "{{\"ph\":\"M\",\"pid\":0,\"tid\":{},\"name\":\"thread_name\",\"args\":{{\"name\":{name}}}}}",
            t.tid
        );
    }
    for t in &threads {
        for s in &t.spans {
            sep(&mut out);
            let _ = write!(
                out,
                "{{\"ph\":\"X\",\"pid\":0,\"tid\":{},\"cat\":\"twilight\",\"name\":\"{}\",\"ts\":{:.3},\"dur\":{:.3},\"args\":{{",
                t.tid,
                s.stage.name(),
                s.begin_ns as f64 / 1e3,
                s.dur_ns as f64 / 1e3,
            );
            let mut afirst = true;
            let mut arg = |out: &mut String, k: &str, v: u64| {
                if afirst {
                    afirst = false;
                } else {
                    out.push(',');
                }
                let _ = write!(out, "\"{k}\":{v}");
            };
            if s.tags.step != u32::MAX {
                arg(&mut out, "step", s.tags.step as u64);
            }
            if s.tags.seq != u32::MAX {
                arg(&mut out, "seq", s.tags.seq as u64);
            }
            if s.tags.layer != u16::MAX {
                arg(&mut out, "layer", s.tags.layer as u64);
            }
            if s.tags.kv_head != u16::MAX {
                arg(&mut out, "kv_head", s.tags.kv_head as u64);
            }
            out.push_str("}}");
        }
    }
    out.push_str("]}");
    out
}

/// Write [`render_chrome`] to `path`.
pub fn export_chrome(path: &str) -> std::io::Result<()> {
    std::fs::write(path, render_chrome())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_wraps_keeping_newest() {
        let r = SpanRing::new(4, "t".to_string());
        for i in 0..10u64 {
            r.push(Stage::Select, i * 100, 10, Tags::NONE);
        }
        let (spans, dropped) = r.decode();
        assert_eq!(spans.len(), 4);
        assert_eq!(dropped, 6);
        assert_eq!(spans.first().unwrap().begin_ns, 600);
        assert_eq!(spans.last().unwrap().begin_ns, 900);
    }

    #[test]
    fn tags_roundtrip_through_packing() {
        let r = SpanRing::new(8, "t".to_string());
        let tags = Tags { step: 41_203, seq: 3, layer: 2, kv_head: 1 };
        r.push(Stage::ToppSearch, 123, 456, tags);
        r.push(Stage::Step, 7, 8, Tags::NONE);
        let (spans, _) = r.decode();
        assert_eq!(spans[0].stage, Stage::ToppSearch);
        assert_eq!(spans[0].tags, tags);
        assert_eq!(spans[0].begin_ns, 123);
        assert_eq!(spans[0].dur_ns, 456);
        assert_eq!(spans[1].tags, Tags::NONE);
    }

    #[test]
    fn disabled_record_is_a_noop_and_chrome_renders_valid_json() {
        // Force off: record must not create this thread's ring entry
        // count (other tests/threads may own rings; count deltas only).
        set_enabled(false);
        let before = event_counts();
        record(Stage::Select, Duration::from_micros(5), Tags::NONE);
        assert_eq!(event_counts(), before, "disabled record must not record");
        assert_eq!(mark(), 0);
        // On: record, then check the export parses and contains it.
        set_enabled(true);
        let t = timer();
        std::hint::black_box(0u64);
        stop_ctx(t, Stage::Unembed);
        set_enabled(false);
        let rendered = render_chrome();
        let parsed = crate::util::json::Json::parse(&rendered).expect("chrome JSON parses");
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(
            events.iter().any(|e| e.get_str("name") == Some("unembed")),
            "recorded span missing from export"
        );
        for e in events {
            let ph = e.get_str("ph").unwrap();
            assert!(ph == "X" || ph == "M");
            if ph == "X" {
                assert!(e.get_f64("ts").is_some() && e.get_f64("dur").is_some());
            }
        }
    }

    #[test]
    fn stage_names_are_unique() {
        let mut names: Vec<&str> = Stage::ALL.iter().map(|s| s.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), N_STAGES);
        for (i, s) in Stage::ALL.iter().enumerate() {
            assert_eq!(*s as usize, i, "Stage::ALL must be discriminant-ordered");
        }
    }
}
