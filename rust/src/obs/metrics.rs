//! Metrics registry: named counters, gauges, and log-bucketed
//! histograms with Prometheus-text exposition.
//!
//! The registry is global and always-on (unlike span tracing there is
//! no toggle: a handful of atomics per scheduler step is noise). Hot
//! paths never touch the registry map — callers resolve a `&'static`
//! handle once (e.g. in `Scheduler::new` or a `OnceLock`) and then
//! every observation is one or two relaxed atomic RMWs, allocation-free.
//!
//! Exposition (`render_prometheus`) emits the Prometheus text format —
//! `# HELP`/`# TYPE` headers, cumulative `_bucket{le="…"}` lines for
//! histograms, and a terminating `# EOF` line so a raw TCP scrape of
//! `{"cmd":"metrics"}` (see `coordinator/server.rs`) knows where the
//! body ends without Content-Length framing.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Monotonic counter.
pub struct Counter {
    v: AtomicU64,
}

impl Default for Counter {
    fn default() -> Counter {
        Counter::new()
    }
}

impl Counter {
    pub const fn new() -> Counter {
        Counter { v: AtomicU64::new(0) }
    }

    #[inline]
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge holding an `f64` (stored as its bit pattern).
pub struct Gauge {
    bits: AtomicU64,
}

impl Default for Gauge {
    fn default() -> Gauge {
        Gauge::new()
    }
}

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge { bits: AtomicU64::new(0) }
    }

    #[inline]
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Histogram bucket count: powers of two spanning `LO = 1e-6` up to
/// `LO * 2^(N_BUCKETS-1)` (≈ 550 for seconds-valued series — wide
/// enough for TTFT and kept-budget token counts alike).
pub const N_BUCKETS: usize = 40;
const LO: f64 = 1e-6;

/// Log2-bucketed histogram: `bucket[i]` counts observations with
/// `v <= LO * 2^i` (first bucket also absorbs everything below `LO`,
/// the last also absorbs everything above — rendered as `+Inf`).
pub struct LogHist {
    buckets: [AtomicU64; N_BUCKETS],
    count: AtomicU64,
    /// CAS-accumulated `f64` sum (observation rates here are ~per-step,
    /// so CAS contention is irrelevant).
    sum_bits: AtomicU64,
}

impl Default for LogHist {
    fn default() -> LogHist {
        LogHist::new()
    }
}

impl LogHist {
    pub const fn new() -> LogHist {
        LogHist {
            buckets: [const { AtomicU64::new(0) }; N_BUCKETS],
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0),
        }
    }

    /// Upper bound of bucket `i` (`+Inf` for the last).
    pub fn le(i: usize) -> f64 {
        if i + 1 >= N_BUCKETS {
            f64::INFINITY
        } else {
            LO * (1u64 << i) as f64
        }
    }

    fn bucket_of(v: f64) -> usize {
        // NaN lands in bucket 0 (observe() sanitizes it to 0.0 anyway).
        if v.is_nan() || v <= LO {
            return 0;
        }
        // ceil(log2(v / LO)) without libm: walk the exponent.
        let ratio = v / LO;
        let mut i = ratio.log2().ceil() as isize;
        // Float edge: ensure the invariant v <= le(i) actually holds.
        while i > 0 && v <= LogHist::le((i - 1) as usize) {
            i -= 1;
        }
        (i.max(0) as usize).min(N_BUCKETS - 1)
    }

    #[inline]
    pub fn observe(&self, v: f64) {
        let v = if v.is_finite() { v.max(0.0) } else { 0.0 };
        self.buckets[LogHist::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    /// Non-cumulative per-bucket counts (exposition cumulates them).
    pub fn bucket_counts(&self) -> [u64; N_BUCKETS] {
        let mut out = [0; N_BUCKETS];
        for (o, b) in out.iter_mut().zip(self.buckets.iter()) {
            *o = b.load(Ordering::Relaxed);
        }
        out
    }
}

enum Metric {
    Counter(&'static Counter),
    Gauge(&'static Gauge),
    Hist(&'static LogHist),
}

struct Entry {
    help: &'static str,
    metric: Metric,
}

fn registry() -> &'static Mutex<BTreeMap<&'static str, Entry>> {
    static R: OnceLock<Mutex<BTreeMap<&'static str, Entry>>> = OnceLock::new();
    R.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Get-or-register the counter `name`. The handle is `'static`: resolve
/// once, observe forever without touching the registry lock.
///
/// Panics if `name` is already registered as a different metric type.
pub fn counter(name: &'static str, help: &'static str) -> &'static Counter {
    let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    let e = reg.entry(name).or_insert_with(|| Entry {
        help,
        metric: Metric::Counter(Box::leak(Box::new(Counter::new()))),
    });
    match e.metric {
        Metric::Counter(c) => c,
        _ => panic!("metric {name} already registered with a different type"),
    }
}

/// Get-or-register the gauge `name` (see [`counter`] for semantics).
pub fn gauge(name: &'static str, help: &'static str) -> &'static Gauge {
    let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    let e = reg.entry(name).or_insert_with(|| Entry {
        help,
        metric: Metric::Gauge(Box::leak(Box::new(Gauge::new()))),
    });
    match e.metric {
        Metric::Gauge(g) => g,
        _ => panic!("metric {name} already registered with a different type"),
    }
}

/// Get-or-register the histogram `name` (see [`counter`] for semantics).
pub fn histogram(name: &'static str, help: &'static str) -> &'static LogHist {
    let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    let e = reg.entry(name).or_insert_with(|| Entry {
        help,
        metric: Metric::Hist(Box::leak(Box::new(LogHist::new()))),
    });
    match e.metric {
        Metric::Hist(h) => h,
        _ => panic!("metric {name} already registered with a different type"),
    }
}

fn fmt_f64(v: f64) -> String {
    if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == v.trunc() && v.abs() < 1e15 {
        format!("{v}")
    } else {
        format!("{v:.9}")
    }
}

/// Render every registered metric in Prometheus text format, terminated
/// by a `# EOF` line (OpenMetrics-style end marker for raw scrapes).
pub fn render_prometheus() -> String {
    use std::fmt::Write;
    let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    let mut out = String::with_capacity(1 << 12);
    for (name, e) in reg.iter() {
        let _ = writeln!(out, "# HELP {name} {}", e.help);
        match e.metric {
            Metric::Counter(c) => {
                let _ = writeln!(out, "# TYPE {name} counter");
                let _ = writeln!(out, "{name} {}", c.get());
            }
            Metric::Gauge(g) => {
                let _ = writeln!(out, "# TYPE {name} gauge");
                let _ = writeln!(out, "{name} {}", fmt_f64(g.get()));
            }
            Metric::Hist(h) => {
                let _ = writeln!(out, "# TYPE {name} histogram");
                let counts = h.bucket_counts();
                let mut cum = 0u64;
                for (i, c) in counts.iter().enumerate() {
                    cum += c;
                    let _ = writeln!(
                        out,
                        "{name}_bucket{{le=\"{}\"}} {cum}",
                        fmt_f64(LogHist::le(i))
                    );
                }
                let _ = writeln!(out, "{name}_sum {}", fmt_f64(h.sum()));
                let _ = writeln!(out, "{name}_count {}", h.count());
            }
        }
    }
    out.push_str("# EOF\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hist_bucket_invariant() {
        // Every observation must land in a bucket whose upper bound
        // contains it and whose predecessor does not (modulo clamping).
        for &v in &[0.0, 1e-9, 1e-6, 1.5e-6, 2e-6, 3.3e-4, 0.01, 0.25, 1.0, 7.0, 549.0, 1e9] {
            let i = LogHist::bucket_of(v);
            assert!(v <= LogHist::le(i), "v={v} above its bucket bound le={}", LogHist::le(i));
            if i > 0 && i < N_BUCKETS - 1 {
                assert!(v > LogHist::le(i - 1), "v={v} should be in an earlier bucket");
            }
        }
    }

    #[test]
    fn hist_observe_and_expose() {
        let h = histogram("twilight_test_hist_seconds", "test histogram");
        h.observe(0.001);
        h.observe(0.002);
        h.observe(1.0);
        assert_eq!(h.count(), 3);
        assert!((h.sum() - 1.003).abs() < 1e-12);
        let counts = h.bucket_counts();
        assert_eq!(counts.iter().sum::<u64>(), 3);
        let c = counter("twilight_test_counter_total", "test counter");
        c.add(41);
        c.inc();
        let g = gauge("twilight_test_gauge", "test gauge");
        g.set(0.5);
        let text = render_prometheus();
        assert!(text.contains("# TYPE twilight_test_hist_seconds histogram"));
        assert!(text.contains("twilight_test_hist_seconds_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("twilight_test_hist_seconds_count 3"));
        assert!(text.contains("twilight_test_counter_total 42"));
        assert!(text.contains("twilight_test_gauge 0.5"));
        assert!(text.ends_with("# EOF\n"));
        // Cumulative bucket lines must be monotonically non-decreasing.
        let mut last = 0u64;
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("twilight_test_hist_seconds_bucket") {
                let n: u64 = rest.rsplit(' ').next().unwrap().parse().unwrap();
                assert!(n >= last);
                last = n;
            }
        }
    }

    #[test]
    fn same_handle_resolves_twice() {
        let a = counter("twilight_test_same_total", "x") as *const Counter;
        let b = counter("twilight_test_same_total", "x") as *const Counter;
        assert_eq!(a, b);
    }
}
