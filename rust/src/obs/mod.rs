//! Observability: span tracing, metrics registry, and flight recorder
//! for the pruned-decode pipeline (DESIGN.md §10).
//!
//! Three coupled, dependency-free pieces:
//!
//! * [`trace`] — per-thread lock-free span rings over every pipeline
//!   stage, exportable as Chrome trace-event JSON (`--trace-out`,
//!   `TWILIGHT_TRACE=1`; open in Perfetto / `chrome://tracing`).
//! * [`metrics`] — named counters/gauges/log-bucketed histograms with
//!   Prometheus-text exposition (server `{"cmd":"metrics"}`).
//! * [`recorder`] — bounded ring of recent step summaries, dumped on
//!   panic, SLO breach, or `{"cmd":"dump"}`.
//!
//! All of it is observational only: nothing here feeds back into
//! scheduling, pruning, sampling, or RNG state, so decode output is
//! bit-identical with observability on or off.

pub mod metrics;
pub mod recorder;
pub mod trace;

/// Process-level init: resolve `TWILIGHT_TRACE` once and install the
/// flight-recorder panic hook. Call early in `main`; optional for
/// library users (everything lazily self-initializes).
pub fn init_from_env() {
    let _ = trace::enabled();
    recorder::install_panic_hook();
}
