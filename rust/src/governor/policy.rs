//! Pluggable budget policies: how the governor turns a signal snapshot
//! into a [`BudgetDirective`].
//!
//! * [`StaticPolicy`] — the identity (config-time knobs rule; the ladder
//!   in [`super::pressure`] still overlays). The control baseline.
//! * [`AimdSlo`] — TCP-style additive-increase / multiplicative-decrease
//!   on a single sparsity scale, driven by the TPOT SLO: violations cut
//!   the scale multiplicatively (budgets shrink, steps get faster),
//!   sustained headroom walks it back up additively toward neutral.
//! * [`MassTarget`] — holds the pruner's captured-mass telemetry at a
//!   target and backs off whenever the dense recall probe dips, i.e. it
//!   spends exactly as much budget as the accuracy proxies demand
//!   (Tactic-style budget-from-score-distribution control).

use super::signals::SignalSnapshot;
use super::BudgetDirective;

/// A budget policy. Policies are deterministic state machines: given the
/// same snapshot sequence they emit the same directive sequence (unit
/// tests rely on this).
pub trait GovernorPolicy: Send {
    fn name(&self) -> &'static str;
    /// One decision. Returned directives are clamped by the governor.
    fn decide(&mut self, s: &SignalSnapshot) -> BudgetDirective;
}

/// Parse a policy by CLI name.
pub fn parse_policy(name: &str) -> Option<Box<dyn GovernorPolicy>> {
    match name {
        "static" => Some(Box::new(StaticPolicy)),
        "aimd" | "aimd-slo" => Some(Box::new(AimdSlo::default())),
        "mass" | "mass-target" => Some(Box::new(MassTarget::default())),
        _ => None,
    }
}

/// Identity policy: always neutral.
pub struct StaticPolicy;

impl GovernorPolicy for StaticPolicy {
    fn name(&self) -> &'static str {
        "static"
    }

    fn decide(&mut self, _s: &SignalSnapshot) -> BudgetDirective {
        BudgetDirective::NEUTRAL
    }
}

/// AIMD on one internal scale `s ∈ [min_scale, 1]`:
/// * TPOT EMA over target  → `s *= decrease`
/// * TPOT EMA under target × (1 − headroom) → `s += increase`
///
/// The scale maps to the directive asymmetrically: B0 absorbs the full
/// cut (`budget_scale = s`) while p moves half as far
/// (`p_scale = 0.5 + 0.5·s`) — shrinking the candidate set is cheap to
/// recover from, while cutting p below the distribution's mass knee
/// costs recall (Fig. 9's cliff).
pub struct AimdSlo {
    scale: f64,
    /// Multiplicative back-off factor on violation.
    pub decrease: f64,
    /// Additive recovery step with headroom.
    pub increase: f64,
    /// Floor for the internal scale.
    pub min_scale: f64,
    /// Headroom fraction under target required before recovering.
    pub headroom: f64,
}

impl Default for AimdSlo {
    fn default() -> Self {
        AimdSlo { scale: 1.0, decrease: 0.85, increase: 0.02, min_scale: 0.25, headroom: 0.2 }
    }
}

impl GovernorPolicy for AimdSlo {
    fn name(&self) -> &'static str {
        "aimd"
    }

    fn decide(&mut self, s: &SignalSnapshot) -> BudgetDirective {
        if s.slo_tpot > 0.0 && s.tpot_ema > 0.0 {
            if s.tpot_ema > s.slo_tpot {
                self.scale *= self.decrease;
            } else if s.tpot_ema < s.slo_tpot * (1.0 - self.headroom) {
                self.scale += self.increase;
            }
            self.scale = self.scale.clamp(self.min_scale, 1.0);
        }
        BudgetDirective {
            p_scale: (0.5 + 0.5 * self.scale) as f32,
            budget_scale: self.scale as f32,
            ..BudgetDirective::NEUTRAL
        }
    }
}

/// Holds captured prune mass at `target_mass` and defends the recall
/// floor measured by the dense probe.
pub struct MassTarget {
    p_scale: f64,
    /// Desired captured-mass telemetry level.
    pub target_mass: f64,
    /// Tolerance band around the target.
    pub band: f64,
    /// Probe recall below this forces p back up regardless of mass.
    pub recall_floor: f64,
    /// Additive adjustment step per decision.
    pub step: f64,
}

impl Default for MassTarget {
    fn default() -> Self {
        MassTarget { p_scale: 1.0, target_mass: 0.92, band: 0.03, recall_floor: 0.85, step: 0.01 }
    }
}

impl GovernorPolicy for MassTarget {
    fn name(&self) -> &'static str {
        "mass"
    }

    fn decide(&mut self, s: &SignalSnapshot) -> BudgetDirective {
        if s.probe_recall < self.recall_floor {
            // Estimation is missing true top-p tokens: back off fast.
            self.p_scale += 4.0 * self.step;
        } else if s.mean_mass > 0.0 {
            if s.mean_mass > self.target_mass + self.band {
                self.p_scale -= self.step;
            } else if s.mean_mass < self.target_mass - self.band {
                self.p_scale += self.step;
            }
        }
        self.p_scale = self.p_scale.clamp(0.6, 1.2);
        BudgetDirective { p_scale: self.p_scale as f32, ..BudgetDirective::NEUTRAL }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_is_identity() {
        let mut p = StaticPolicy;
        let d = p.decide(&SignalSnapshot::default());
        assert_eq!(d, BudgetDirective::NEUTRAL);
    }

    #[test]
    fn aimd_converges_on_synthetic_latency_series() {
        // Plant: TPOT responds linearly to the budget scale with a fixed
        // floor — tpot = base · (0.2 + 0.8·budget_scale). With base 20ms
        // and a 10ms SLO the equilibrium is budget_scale ≈ 0.375.
        let mut pol = AimdSlo::default();
        let target = 0.010;
        let base = 0.020;
        let mut snap = SignalSnapshot { slo_tpot: target, tpot_ema: base, ..Default::default() };
        let mut d = BudgetDirective::NEUTRAL;
        for _ in 0..400 {
            d = pol.decide(&snap).clamped();
            snap.tpot_ema = base * (0.2 + 0.8 * d.budget_scale as f64);
        }
        assert!(
            snap.tpot_ema <= target * 1.2,
            "AIMD failed to bring TPOT near target: {} vs {}",
            snap.tpot_ema,
            target
        );
        assert!(
            d.budget_scale > 0.2 && (d.budget_scale as f64) < 0.6,
            "scale should hover near the 0.375 equilibrium, got {}",
            d.budget_scale
        );
        // p is cut by at most half the budget's reduction.
        assert!(d.p_scale >= d.budget_scale);
    }

    #[test]
    fn aimd_recovers_with_headroom() {
        let mut pol = AimdSlo::default();
        let snap_hot =
            SignalSnapshot { slo_tpot: 0.010, tpot_ema: 0.050, ..Default::default() };
        for _ in 0..50 {
            pol.decide(&snap_hot);
        }
        let floor = pol.decide(&snap_hot).clamped();
        assert!((floor.budget_scale as f64 - pol.min_scale).abs() < 1e-6);
        // Load vanishes: scale walks back to neutral additively.
        let snap_idle =
            SignalSnapshot { slo_tpot: 0.010, tpot_ema: 0.001, ..Default::default() };
        let mut d = floor;
        for _ in 0..100 {
            d = pol.decide(&snap_idle).clamped();
        }
        assert!((d.budget_scale - 1.0).abs() < 1e-6, "did not recover: {}", d.budget_scale);
        assert!((d.p_scale - 1.0).abs() < 1e-6);
    }

    #[test]
    fn aimd_holds_without_slo() {
        let mut pol = AimdSlo::default();
        let snap = SignalSnapshot { slo_tpot: 0.0, tpot_ema: 99.0, ..Default::default() };
        for _ in 0..10 {
            let d = pol.decide(&snap);
            assert_eq!(d.budget_scale, 1.0, "no SLO → no adaptation");
        }
    }

    #[test]
    fn mass_target_steers_p_both_ways() {
        let mut pol = MassTarget::default();
        let over = SignalSnapshot { mean_mass: 0.99, ..Default::default() };
        let mut d = BudgetDirective::NEUTRAL;
        for _ in 0..20 {
            d = pol.decide(&over);
        }
        assert!(d.p_scale < 1.0, "overshooting mass must lower p, got {}", d.p_scale);
        let under = SignalSnapshot { mean_mass: 0.5, ..Default::default() };
        for _ in 0..40 {
            d = pol.decide(&under);
        }
        assert!(d.p_scale > 1.0, "starved mass must raise p, got {}", d.p_scale);
    }

    #[test]
    fn mass_target_defends_recall_floor() {
        let mut pol = MassTarget::default();
        // High mass says "prune harder" but the probe says estimation is
        // missing true top-p tokens — recall wins.
        let snap = SignalSnapshot { mean_mass: 0.99, probe_recall: 0.5, ..Default::default() };
        let before = pol.decide(&snap).p_scale;
        let after = pol.decide(&snap).p_scale;
        assert!(after >= before, "recall floor must push p up");
        for _ in 0..40 {
            pol.decide(&snap);
        }
        let d = pol.decide(&snap);
        assert!((d.p_scale - 1.2).abs() < 1e-6, "should saturate at the cap, got {}", d.p_scale);
    }
}
