//! The adaptive budget governor — a runtime control plane that closes
//! the loop on top-p sparsity (DESIGN.md §8).
//!
//! Twilight makes the *per-head* budget adaptive, but the deployment
//! knobs (`p`, the stage-1 budget B0, `dense_below`) are frozen at
//! config time. The governor runs once per scheduler step, aggregates
//! three live signal streams —
//!
//! 1. **accuracy proxies** from the pruner (per-layer captured-mass and
//!    keep-ratio rings, plus a periodic dense recall probe),
//! 2. **latency** (step time ≙ TPOT under continuous batching) vs. a
//!    target SLO,
//! 3. **memory pressure** (page-pool headroom),
//!
//! — and emits a [`BudgetDirective`] the engine applies to every pruned
//! attention call of the next step. Policies ([`policy`]) decide the
//! accuracy/latency trade; the pressure ladder ([`pressure`]) overlays
//! staged degradation so the scheduler is never forced into recompute
//! preemption without the governor having tried cheaper levers first.
//!
//! ```text
//!  engine ──mass/keep/recall──┐
//!  scheduler ──step time──────┤
//!  kv pool ──free pages───────┼──> SignalSnapshot ──> policy ──┐
//!                             │                                v
//!  engine <── BudgetDirective ┴──────────── pressure ladder ───┘
//! ```

pub mod policy;
pub mod pressure;
pub mod signals;
pub mod slo;

use crate::util::json::{self, Json};
use policy::GovernorPolicy;
use pressure::PressureConfig;
use signals::{SignalHub, SignalSnapshot};
use slo::{SloConfig, SloTracker};

/// What the governor tells the engine to do for the next step. All
/// fields are *relative* to the static `SparseConfig`, so a neutral
/// directive reproduces ungoverned behavior exactly.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BudgetDirective {
    /// Multiplier on the pruner threshold p.
    pub p_scale: f32,
    /// Multiplier on the stage-1 candidate budget B0.
    pub budget_scale: f32,
    /// Replaces `SparseConfig::dense_below` when set.
    pub dense_below_override: Option<usize>,
    /// Toggles the pruner's hierarchical page-level top-p pre-prune
    /// (`PrunerConfig::hier_pages`) when set: a policy can switch the
    /// cheaper page-bounded scoring on under load (it trades ≤ hier_eps
    /// of captured mass for skipping cold pages' SpGEMV entirely) or
    /// force it off for accuracy-critical phases. `None` leaves the
    /// configured default in force.
    pub hier_pages_override: Option<bool>,
    /// Toggles the bound-guided sparse *prefill* path
    /// (`SparseConfig::sparse_prefill`, DESIGN.md §13) when set: the
    /// pressure ladder forces it on under load so long-prompt chunks
    /// stop paying the dense O(n²) context walk (trading ≤ eps of each
    /// query's softmax mass), and a policy can force it off for
    /// accuracy-critical phases. `None` leaves the configured default.
    pub sparse_prefill_override: Option<bool>,
    /// Pressure ladder rung (0 = none); the scheduler throttles
    /// admission from level 2 and freezes it at level 3.
    pub degrade_level: u8,
}

impl BudgetDirective {
    pub const NEUTRAL: BudgetDirective = BudgetDirective {
        p_scale: 1.0,
        budget_scale: 1.0,
        dense_below_override: None,
        hier_pages_override: None,
        sparse_prefill_override: None,
        degrade_level: 0,
    };

    /// Hard safety range for the p multiplier.
    pub const P_SCALE_RANGE: (f32, f32) = (0.5, 1.25);
    /// Hard safety range for the budget multiplier.
    pub const BUDGET_SCALE_RANGE: (f32, f32) = (0.2, 1.5);
    /// Ceiling for `dense_below_override`: contexts up to this may be
    /// forced dense, longer ones must stay on the sparse path (a policy
    /// must never be able to disable sparse attention wholesale).
    pub const DENSE_BELOW_MAX: usize = 4096;

    /// Prefill-chunk divisor implied by the pressure ladder: level 2
    /// halves the chunk span, level 3 quarters it — shrinking the
    /// per-step admission work (and the pages a chunk claims) *before*
    /// the scheduler freezes admission outright. Levels 0–1 leave the
    /// chunk alone (p tightening is cheaper to give up first).
    pub fn chunk_divisor(&self) -> usize {
        match self.degrade_level {
            0 | 1 => 1,
            2 => 2,
            _ => 4,
        }
    }

    /// Clamp every field into its safe range. Applied to every policy
    /// output before it reaches the engine, so a buggy policy can
    /// degrade quality but never disable attention entirely.
    pub fn clamped(mut self) -> BudgetDirective {
        let (plo, phi) = Self::P_SCALE_RANGE;
        let (blo, bhi) = Self::BUDGET_SCALE_RANGE;
        self.p_scale = if self.p_scale.is_finite() { self.p_scale.clamp(plo, phi) } else { 1.0 };
        self.budget_scale =
            if self.budget_scale.is_finite() { self.budget_scale.clamp(blo, bhi) } else { 1.0 };
        self.dense_below_override =
            self.dense_below_override.map(|v| v.min(Self::DENSE_BELOW_MAX));
        self.degrade_level = self.degrade_level.min(3);
        self
    }
}

impl Default for BudgetDirective {
    fn default() -> Self {
        BudgetDirective::NEUTRAL
    }
}

/// One governor decision, as recorded in the serving report.
#[derive(Clone, Copy, Debug)]
pub struct TraceEntry {
    /// Virtual time of the decision.
    pub t: f64,
    pub p_scale: f32,
    pub budget_scale: f32,
    pub degrade_level: u8,
    /// Observed TPOT EMA at decision time (seconds).
    pub tpot_ema: f64,
    /// Free page-pool fraction at decision time.
    pub free_frac: f64,
    /// Mean captured prune mass at decision time.
    pub mean_mass: f64,
    /// Mean kept/candidate ratio at decision time.
    pub keep_ratio: f64,
}

/// Governor configuration.
#[derive(Clone, Copy, Debug, Default)]
pub struct GovernorConfig {
    pub slo: SloConfig,
    pub pressure: PressureConfig,
}

/// The control plane: one per scheduler.
pub struct Governor {
    /// Construction-time configuration. The *live* SLO target is owned
    /// by the tracker (`slo.cfg`) — read it via [`Governor::slo_tpot`].
    pub cfg: GovernorConfig,
    slo: SloTracker,
    policy: Box<dyn GovernorPolicy>,
    /// The policy's latest output, before the pressure overlay.
    policy_directive: BudgetDirective,
    directive: BudgetDirective,
    trace: Vec<TraceEntry>,
    decisions: u64,
    /// Freshness markers: the policy only advances when at least one new
    /// observation (engine step or latency sample) landed since its last
    /// decision, so its AI/MD rates track *load*, not the scheduler's
    /// idle-spin frequency.
    last_steps: u64,
    last_obs: u64,
}

impl Governor {
    /// Build from a policy name (`static` | `aimd` | `mass`).
    pub fn new(policy_name: &str, cfg: GovernorConfig) -> Option<Governor> {
        let policy = policy::parse_policy(policy_name)?;
        Some(Governor {
            cfg,
            slo: SloTracker::new(cfg.slo),
            policy,
            policy_directive: BudgetDirective::NEUTRAL,
            directive: BudgetDirective::NEUTRAL,
            trace: Vec::new(),
            decisions: 0,
            last_steps: u64::MAX,
            last_obs: u64::MAX,
        })
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Report one finished scheduler step to the latency tracker.
    pub fn observe_step(&mut self, step_secs: f64, produced: usize) {
        self.slo.observe_step(step_secs, produced);
    }

    /// Change the TPOT SLO at runtime (server `slo` command / CLI). The
    /// tracker owns the live target; `cfg.slo` stays as-constructed.
    pub fn set_slo_tpot(&mut self, target_tpot_s: f64) {
        self.slo.set_target(target_tpot_s);
    }

    pub fn slo_tpot(&self) -> f64 {
        self.slo.cfg.target_tpot_s
    }

    /// Smoothed per-token latency the SLO tracker currently sees (0.0
    /// until the first observed step). Observability reads this to flag
    /// SLO-breach anomalies without reaching into the tracker.
    pub fn tpot_ema(&self) -> f64 {
        self.slo.tpot_ema()
    }

    /// Assemble the snapshot a policy will see. `tier_fault_ema` is the
    /// scheduler's smoothed tier faults/step (0.0 when no offload tier
    /// is attached).
    #[allow(clippy::too_many_arguments)]
    pub fn snapshot(
        &self,
        now: f64,
        hub: &SignalHub,
        free_frac: f64,
        queue_depth: usize,
        running: usize,
        tier_fault_ema: f64,
        steps: u64,
    ) -> SignalSnapshot {
        SignalSnapshot {
            now,
            tpot_ema: self.slo.tpot_ema(),
            slo_tpot: self.slo.cfg.target_tpot_s,
            free_frac,
            queue_depth,
            running,
            mean_mass: hub.mean_mass(),
            mean_keep_ratio: hub.mean_keep_ratio(),
            probe_recall: hub.probe_recall(),
            tier_fault_ema,
            steps,
        }
    }

    /// One decision: policy → clamp → pressure overlay → clamp.
    ///
    /// The *policy* only advances on fresh observations (a new engine
    /// step or latency sample): a scheduler spinning idle on future
    /// arrivals calls this thousands of times per second, and letting a
    /// stateful policy integrate stale signals that fast would slam its
    /// scale to a clamp within microseconds. The pressure overlay is
    /// stateless and reapplies every call. The trace records every
    /// *changed* directive plus a periodic heartbeat.
    pub fn step(&mut self, snap: &SignalSnapshot) -> BudgetDirective {
        let obs = self.slo.observations();
        let fresh = self.last_steps != snap.steps || self.last_obs != obs;
        if fresh {
            self.last_steps = snap.steps;
            self.last_obs = obs;
            self.policy_directive = self.policy.decide(snap).clamped();
        }
        let mut d = self.policy_directive;
        // Effective rung = max of memory pressure and sustained tier
        // faults (the fault rung caps at 2 — see pressure.rs).
        let level = self
            .cfg
            .pressure
            .level(snap.free_frac)
            .max(self.cfg.pressure.fault_level(snap.tier_fault_ema));
        self.cfg.pressure.apply(level, &mut d);
        let d = d.clamped();
        let changed = d != self.directive;
        self.directive = d;
        self.decisions += 1;
        if changed || self.trace.is_empty() || self.decisions % 16 == 0 {
            // Bound the trace for never-drained deployments (the TCP
            // server runs indefinitely): drop the oldest half when full.
            const MAX_TRACE: usize = 16384;
            if self.trace.len() >= MAX_TRACE {
                self.trace.drain(..MAX_TRACE / 2);
            }
            self.trace.push(TraceEntry {
                t: snap.now,
                p_scale: d.p_scale,
                budget_scale: d.budget_scale,
                degrade_level: d.degrade_level,
                tpot_ema: snap.tpot_ema,
                free_frac: snap.free_frac,
                mean_mass: snap.mean_mass,
                keep_ratio: snap.mean_keep_ratio,
            });
        }
        d
    }

    /// The directive currently in force.
    pub fn directive(&self) -> BudgetDirective {
        self.directive
    }

    pub fn trace(&self) -> &[TraceEntry] {
        &self.trace
    }

    /// Drain the trace (the scheduler moves it into the serving report).
    pub fn take_trace(&mut self) -> Vec<TraceEntry> {
        std::mem::take(&mut self.trace)
    }

    pub fn decisions(&self) -> u64 {
        self.decisions
    }

    /// Live state for the server's `stats` command.
    pub fn state_json(&self) -> Json {
        json::obj(vec![
            ("policy", json::s(self.policy.name())),
            ("p_scale", Json::Num(self.directive.p_scale as f64)),
            ("budget_scale", Json::Num(self.directive.budget_scale as f64)),
            ("degrade_level", Json::Num(self.directive.degrade_level as f64)),
            (
                "dense_below_override",
                match self.directive.dense_below_override {
                    Some(v) => Json::Num(v as f64),
                    None => Json::Null,
                },
            ),
            (
                "hier_pages_override",
                match self.directive.hier_pages_override {
                    Some(v) => Json::Bool(v),
                    None => Json::Null,
                },
            ),
            (
                "sparse_prefill_override",
                match self.directive.sparse_prefill_override {
                    Some(v) => Json::Bool(v),
                    None => Json::Null,
                },
            ),
            ("slo_tpot_ms", Json::Num(self.slo.cfg.target_tpot_s * 1e3)),
            ("tpot_ema_ms", Json::Num(self.slo.tpot_ema() * 1e3)),
            ("slo_violation_rate", Json::Num(self.slo.violation_rate())),
            ("decisions", Json::Num(self.decisions as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_policy_rejected() {
        assert!(Governor::new("nope", GovernorConfig::default()).is_none());
        assert!(Governor::new("aimd", GovernorConfig::default()).is_some());
        assert!(Governor::new("static", GovernorConfig::default()).is_some());
        assert!(Governor::new("mass", GovernorConfig::default()).is_some());
    }

    #[test]
    fn directives_always_clamped_to_safe_ranges() {
        let wild = BudgetDirective {
            p_scale: 9.0,
            budget_scale: 0.0,
            dense_below_override: Some(1 << 20),
            hier_pages_override: Some(true),
            sparse_prefill_override: Some(true),
            degrade_level: 99,
        }
        .clamped();
        assert_eq!(wild.p_scale, BudgetDirective::P_SCALE_RANGE.1);
        assert_eq!(wild.budget_scale, BudgetDirective::BUDGET_SCALE_RANGE.0);
        assert_eq!(wild.dense_below_override, Some(BudgetDirective::DENSE_BELOW_MAX));
        assert_eq!(wild.hier_pages_override, Some(true), "bool knob passes through clamping");
        assert_eq!(wild.degrade_level, 3);
        let nan = BudgetDirective {
            p_scale: f32::NAN,
            budget_scale: f32::NEG_INFINITY,
            ..BudgetDirective::NEUTRAL
        }
        .clamped();
        assert_eq!(nan.p_scale, 1.0);
        assert_eq!(nan.budget_scale, 1.0);
    }

    #[test]
    fn chunk_divisor_follows_ladder() {
        let at = |lvl: u8| BudgetDirective { degrade_level: lvl, ..BudgetDirective::NEUTRAL };
        assert_eq!(at(0).chunk_divisor(), 1);
        assert_eq!(at(1).chunk_divisor(), 1);
        assert_eq!(at(2).chunk_divisor(), 2);
        assert_eq!(at(3).chunk_divisor(), 4);
    }

    #[test]
    fn sustained_tier_faults_degrade_without_memory_pressure() {
        let mut g = Governor::new("static", GovernorConfig::default()).unwrap();
        // Plenty of page headroom, but the offload tier is failing.
        let snap = SignalSnapshot { free_frac: 0.9, tier_fault_ema: 5.0, ..Default::default() };
        let d = g.step(&snap);
        assert_eq!(d.degrade_level, 2, "fault rung caps below admission freeze");
        assert!(d.p_scale < 1.0);
        assert!(d.budget_scale < 1.0);
        assert_eq!(d.sparse_prefill_override, Some(true));
        // A healthy tier with the same headroom stays neutral.
        let calm = SignalSnapshot { free_frac: 0.9, ..Default::default() };
        assert_eq!(g.step(&calm).degrade_level, 0);
    }

    #[test]
    fn pressure_overlays_any_policy() {
        // Even the static policy degrades under pressure.
        let mut g = Governor::new("static", GovernorConfig::default()).unwrap();
        let snap = SignalSnapshot { free_frac: 0.01, ..Default::default() };
        let d = g.step(&snap);
        assert_eq!(d.degrade_level, 3);
        assert!(d.p_scale < 1.0);
        assert!(d.budget_scale < 1.0);
        assert!(d.dense_below_override.is_some());
        assert_eq!(g.trace().len(), 1);
        assert_eq!(g.directive(), d);
    }

    #[test]
    fn aimd_governor_reacts_to_slo_violation() {
        let mut g = Governor::new(
            "aimd",
            GovernorConfig {
                slo: slo::SloConfig { target_tpot_s: 0.010, margin: 0.2 },
                ..Default::default()
            },
        )
        .unwrap();
        let hub = SignalHub::new(1);
        // Steps twice as slow as the SLO allows.
        for i in 0..20u64 {
            g.observe_step(0.020, 4);
            let snap = g.snapshot(i as f64 * 0.02, &hub, 0.9, 0, 4, 0.0, i);
            g.step(&snap);
        }
        let d = g.directive();
        assert!(d.budget_scale < 1.0, "governor must tighten under violation");
        assert!(d.p_scale < 1.0);
        assert_eq!(d.degrade_level, 0, "no memory pressure here");
        // Trace must show the movement.
        let first = g.trace().first().unwrap().budget_scale;
        let last = g.trace().last().unwrap().budget_scale;
        assert!(last < first);
        let j = g.state_json();
        assert_eq!(j.get_str("policy"), Some("aimd"));
        assert!(j.get_f64("tpot_ema_ms").unwrap() > 0.0);
    }

    #[test]
    fn policy_state_freezes_without_fresh_observations() {
        // An idle scheduler spinning on future arrivals calls step() at
        // megahertz rates with frozen signals; the policy must hold, not
        // integrate the stale EMA until it slams into a clamp.
        let mut g = Governor::new(
            "aimd",
            GovernorConfig {
                slo: slo::SloConfig { target_tpot_s: 0.010, margin: 0.2 },
                ..Default::default()
            },
        )
        .unwrap();
        let hub = SignalHub::new(1);
        g.observe_step(0.020, 1); // one violating latency sample
        let snap = g.snapshot(0.0, &hub, 0.9, 0, 1, 0.0, 1);
        let first = g.step(&snap);
        assert!(first.budget_scale < 1.0);
        let mut held = first;
        for _ in 0..1000 {
            held = g.step(&snap);
        }
        assert_eq!(held, first, "stale signals must not advance the policy");
        // A fresh observation resumes adaptation.
        g.observe_step(0.020, 1);
        let snap2 = g.snapshot(0.1, &hub, 0.9, 0, 1, 0.0, 2);
        let next = g.step(&snap2);
        assert!(next.budget_scale < first.budget_scale);
    }

    #[test]
    fn trace_drains_once() {
        let mut g = Governor::new("static", GovernorConfig::default()).unwrap();
        g.step(&SignalSnapshot::default());
        assert_eq!(g.take_trace().len(), 1);
        assert!(g.trace().is_empty());
    }
}
