//! Signal aggregation: the telemetry streams the governor closes the
//! loop on (DESIGN.md §8).
//!
//! Three producers feed the hub:
//! * the **engine** records per-layer prune telemetry (estimated mass
//!   captured, kept/candidate ratio) into bounded rings after every
//!   pruned attention call, plus a periodic *recall probe* — one pruned
//!   head re-scored densely via `PagedKvCache::exact_score` to measure
//!   estimated-vs-true top-p recall;
//! * the **scheduler** reports step latency to the [`super::slo`]
//!   tracker and page-pool headroom;
//! * the governor snapshots everything once per scheduler step into a
//!   [`SignalSnapshot`] for the policy to consume.

/// Exponential moving average; seeds on the first sample.
#[derive(Clone, Copy, Debug)]
pub struct Ema {
    alpha: f64,
    value: f64,
    samples: u64,
}

impl Ema {
    pub fn new(alpha: f64) -> Ema {
        Ema { alpha, value: 0.0, samples: 0 }
    }

    pub fn push(&mut self, x: f64) {
        if self.samples == 0 {
            self.value = x;
        } else {
            self.value = self.alpha * x + (1.0 - self.alpha) * self.value;
        }
        self.samples += 1;
    }

    pub fn get(&self) -> f64 {
        self.value
    }

    /// True once at least one sample has landed.
    pub fn is_warm(&self) -> bool {
        self.samples > 0
    }

    pub fn samples(&self) -> u64 {
        self.samples
    }
}

/// Fixed-capacity ring of recent observations with an O(1) running sum.
#[derive(Clone, Debug)]
pub struct Ring {
    buf: Vec<f64>,
    next: usize,
    filled: usize,
    sum: f64,
}

impl Ring {
    pub fn new(capacity: usize) -> Ring {
        assert!(capacity > 0);
        Ring { buf: vec![0.0; capacity], next: 0, filled: 0, sum: 0.0 }
    }

    pub fn push(&mut self, x: f64) {
        if self.filled == self.buf.len() {
            self.sum -= self.buf[self.next];
        } else {
            self.filled += 1;
        }
        self.sum += x;
        self.buf[self.next] = x;
        self.next = (self.next + 1) % self.buf.len();
    }

    pub fn len(&self) -> usize {
        self.filled
    }

    pub fn is_empty(&self) -> bool {
        self.filled == 0
    }

    pub fn mean(&self) -> f64 {
        if self.filled == 0 {
            0.0
        } else {
            self.sum / self.filled as f64
        }
    }
}

/// Per-layer prune telemetry ring pair.
#[derive(Clone, Debug)]
pub struct LayerSignal {
    /// Estimated attention mass captured by the kept set (mean over the
    /// GQA group per call).
    pub mass: Ring,
    /// |kept-union| / |candidates| per call.
    pub keep_ratio: Ring,
}

impl LayerSignal {
    fn new(window: usize) -> LayerSignal {
        LayerSignal { mass: Ring::new(window), keep_ratio: Ring::new(window) }
    }
}

/// Default ring window (per layer, in pruned attention calls).
pub const DEFAULT_WINDOW: usize = 256;

/// Default recall-probe cadence (one probe per this many sparse calls).
pub const DEFAULT_PROBE_INTERVAL: u64 = 64;

/// The accuracy-proxy signal store, owned by the engine.
#[derive(Clone, Debug)]
pub struct SignalHub {
    layers: Vec<LayerSignal>,
    probe_recall: Ema,
    probe_interval: u64,
    /// Hierarchical page pre-prune accounting (cumulative): candidate
    /// page runs seen and page runs skipped unscored. Zero unless
    /// `hier_pages` mode ran.
    hier_skipped: u64,
    hier_total: u64,
}

impl SignalHub {
    pub fn new(n_layers: usize) -> SignalHub {
        SignalHub {
            layers: (0..n_layers).map(|_| LayerSignal::new(DEFAULT_WINDOW)).collect(),
            probe_recall: Ema::new(0.2),
            probe_interval: DEFAULT_PROBE_INTERVAL,
            hier_skipped: 0,
            hier_total: 0,
        }
    }

    /// Record one hier-pages prune call's page accounting.
    pub fn record_hier(&mut self, skipped: u64, total: u64) {
        self.hier_skipped += skipped;
        self.hier_total += total;
    }

    /// Cumulative candidate page runs skipped by the hier pre-prune.
    pub fn hier_pages_skipped(&self) -> u64 {
        self.hier_skipped
    }

    /// Cumulative candidate page runs seen by the hier pre-prune.
    pub fn hier_pages_total(&self) -> u64 {
        self.hier_total
    }

    /// Fraction of candidate pages the hier pre-prune skipped (0 when the
    /// mode never ran).
    pub fn hier_skip_frac(&self) -> f64 {
        if self.hier_total == 0 {
            0.0
        } else {
            self.hier_skipped as f64 / self.hier_total as f64
        }
    }

    /// Record one pruned attention call's telemetry for `layer`.
    pub fn record_prune(&mut self, layer: usize, mean_mass: f64, keep_ratio: f64) {
        if let Some(l) = self.layers.get_mut(layer) {
            l.mass.push(mean_mass);
            l.keep_ratio.push(keep_ratio);
        }
    }

    /// True when the periodic recall probe should run on this call. The
    /// reference cadence predicate: the engine evaluates the same test
    /// from call indices precomputed at work-list flatten time (via
    /// [`SignalHub::probe_interval`]) so the cadence is identical for
    /// any attention worker count.
    pub fn probe_due(&self, sparse_calls: u64) -> bool {
        self.probe_interval > 0 && sparse_calls % self.probe_interval == 0
    }

    /// Probe cadence (sparse calls between probes; 0 disables). The
    /// engine snapshots this before a parallel attention phase so workers
    /// can evaluate the cadence from precomputed call indices.
    pub fn probe_interval(&self) -> u64 {
        self.probe_interval
    }

    /// Record an estimated-vs-true top-p recall measurement (0..=1).
    pub fn record_probe(&mut self, recall: f64) {
        self.probe_recall.push(recall.clamp(0.0, 1.0));
    }

    /// EMA of probe recall; 1.0 until the first probe lands (optimistic:
    /// no evidence of estimation error yet).
    pub fn probe_recall(&self) -> f64 {
        if self.probe_recall.is_warm() {
            self.probe_recall.get()
        } else {
            1.0
        }
    }

    pub fn probes(&self) -> u64 {
        self.probe_recall.samples()
    }

    /// Number of per-layer telemetry rings (the model's layer count).
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Per-layer window means, for reports.
    pub fn layer_mass(&self, layer: usize) -> f64 {
        self.layers.get(layer).map(|l| l.mass.mean()).unwrap_or(0.0)
    }

    /// Mean captured mass across layers with data.
    pub fn mean_mass(&self) -> f64 {
        mean_over(self.layers.iter().filter(|l| !l.mass.is_empty()).map(|l| l.mass.mean()))
    }

    /// Mean kept/candidate ratio across layers with data.
    pub fn mean_keep_ratio(&self) -> f64 {
        mean_over(
            self.layers
                .iter()
                .filter(|l| !l.keep_ratio.is_empty())
                .map(|l| l.keep_ratio.mean()),
        )
    }

    /// True once any prune telemetry has been recorded.
    pub fn has_prune_data(&self) -> bool {
        self.layers.iter().any(|l| !l.mass.is_empty())
    }
}

fn mean_over<I: Iterator<Item = f64>>(it: I) -> f64 {
    let mut sum = 0.0;
    let mut n = 0usize;
    for x in it {
        sum += x;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

/// Everything a policy sees for one decision, in one flat struct.
#[derive(Clone, Copy, Debug)]
pub struct SignalSnapshot {
    /// Virtual time of the decision (seconds since trace start).
    pub now: f64,
    /// EMA of observed time-per-output-token (seconds); 0 until warm.
    pub tpot_ema: f64,
    /// TPOT target from the SLO (seconds); 0 disables latency control.
    pub slo_tpot: f64,
    /// Free fraction of the KV page pool (0 = exhausted).
    pub free_frac: f64,
    /// Requests waiting for admission.
    pub queue_depth: usize,
    /// Requests currently decoding.
    pub running: usize,
    /// Mean estimated mass captured by pruning (window mean over layers).
    pub mean_mass: f64,
    /// Mean kept/candidate ratio.
    pub mean_keep_ratio: f64,
    /// EMA of the dense recall probe (1.0 until the first probe).
    pub probe_recall: f64,
    /// Smoothed offload-tier faults per step (read + write errors +
    /// lost pages; 0 with no tier attached or a healthy one). Feeds the
    /// pressure ladder's fault rung (DESIGN.md §14).
    pub tier_fault_ema: f64,
    /// Engine decode steps so far.
    pub steps: u64,
}

impl Default for SignalSnapshot {
    fn default() -> Self {
        SignalSnapshot {
            now: 0.0,
            tpot_ema: 0.0,
            slo_tpot: 0.0,
            free_frac: 1.0,
            queue_depth: 0,
            running: 0,
            mean_mass: 0.0,
            mean_keep_ratio: 0.0,
            probe_recall: 1.0,
            tier_fault_ema: 0.0,
            steps: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ema_seeds_then_smooths() {
        let mut e = Ema::new(0.5);
        assert!(!e.is_warm());
        e.push(10.0);
        assert_eq!(e.get(), 10.0);
        e.push(0.0);
        assert!((e.get() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn ring_mean_over_window() {
        let mut r = Ring::new(4);
        assert_eq!(r.mean(), 0.0);
        for x in [1.0, 2.0, 3.0, 4.0] {
            r.push(x);
        }
        assert!((r.mean() - 2.5).abs() < 1e-12);
        r.push(5.0); // evicts 1.0
        assert_eq!(r.len(), 4);
        assert!((r.mean() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn hub_aggregates_layers() {
        let mut h = SignalHub::new(2);
        assert!(!h.has_prune_data());
        assert_eq!(h.probe_recall(), 1.0);
        h.record_prune(0, 0.9, 0.2);
        h.record_prune(1, 0.7, 0.4);
        assert!(h.has_prune_data());
        assert!((h.mean_mass() - 0.8).abs() < 1e-12);
        assert!((h.mean_keep_ratio() - 0.3).abs() < 1e-12);
        assert!((h.layer_mass(1) - 0.7).abs() < 1e-12);
        // Out-of-range layer: silently ignored (dense layers never record).
        h.record_prune(9, 1.0, 1.0);
        h.record_probe(0.5);
        assert!(h.probe_recall() < 1.0);
        assert_eq!(h.probes(), 1);
    }

    #[test]
    fn hier_counters_accumulate() {
        let mut h = SignalHub::new(1);
        assert_eq!(h.hier_skip_frac(), 0.0, "no hier data: frac is 0");
        h.record_hier(3, 10);
        h.record_hier(2, 10);
        assert_eq!(h.hier_pages_skipped(), 5);
        assert_eq!(h.hier_pages_total(), 20);
        assert!((h.hier_skip_frac() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn probe_cadence() {
        let h = SignalHub::new(1);
        assert!(h.probe_due(0));
        assert!(!h.probe_due(1));
        assert!(h.probe_due(DEFAULT_PROBE_INTERVAL));
    }
}
