//! Latency SLO tracking: observed step time vs. a TPOT target.
//!
//! Under continuous batching every running request advances one token
//! per scheduler step, so the per-request time-per-output-token *is* the
//! step duration — the tracker EMAs step durations (only steps that
//! actually produced tokens; admission-only steps are skipped) and
//! classifies the current state against the target.

use super::signals::Ema;

/// SLO configuration.
#[derive(Clone, Copy, Debug)]
pub struct SloConfig {
    /// Target time-per-output-token in seconds. `0.0` disables latency
    /// control (the tracker still measures).
    pub target_tpot_s: f64,
    /// Comfort margin: observed TPOT below `target * (1 - margin)` counts
    /// as headroom (safe to relax sparsity).
    pub margin: f64,
}

impl Default for SloConfig {
    fn default() -> Self {
        SloConfig { target_tpot_s: 0.0, margin: 0.2 }
    }
}

/// Step-latency tracker.
#[derive(Clone, Debug)]
pub struct SloTracker {
    pub cfg: SloConfig,
    tpot: Ema,
    observations: u64,
    violations: u64,
}

impl SloTracker {
    pub fn new(cfg: SloConfig) -> SloTracker {
        SloTracker { cfg, tpot: Ema::new(0.1), observations: 0, violations: 0 }
    }

    /// Record one scheduler step: wall-clock duration and tokens produced.
    pub fn observe_step(&mut self, step_secs: f64, produced: usize) {
        if produced == 0 {
            return;
        }
        self.tpot.push(step_secs);
        self.observations += 1;
        if self.cfg.target_tpot_s > 0.0 && step_secs > self.cfg.target_tpot_s {
            self.violations += 1;
        }
    }

    /// Current TPOT EMA (seconds); 0 until the first observation.
    pub fn tpot_ema(&self) -> f64 {
        if self.tpot.is_warm() {
            self.tpot.get()
        } else {
            0.0
        }
    }

    pub fn observations(&self) -> u64 {
        self.observations
    }

    /// Fraction of observed steps over target.
    pub fn violation_rate(&self) -> f64 {
        if self.observations == 0 {
            0.0
        } else {
            self.violations as f64 / self.observations as f64
        }
    }

    /// Observed EMA exceeds the target.
    pub fn is_violating(&self) -> bool {
        self.cfg.target_tpot_s > 0.0 && self.tpot.is_warm() && self.tpot.get() > self.cfg.target_tpot_s
    }

    /// Observed EMA is comfortably under the target.
    pub fn has_headroom(&self) -> bool {
        self.cfg.target_tpot_s > 0.0
            && self.tpot.is_warm()
            && self.tpot.get() < self.cfg.target_tpot_s * (1.0 - self.cfg.margin)
    }

    /// Change the target at runtime (the server's `slo` command).
    pub fn set_target(&mut self, target_tpot_s: f64) {
        self.cfg.target_tpot_s = target_tpot_s.max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_violations_and_headroom() {
        let mut t = SloTracker::new(SloConfig { target_tpot_s: 0.010, margin: 0.2 });
        assert!(!t.is_violating());
        assert!(!t.has_headroom());
        t.observe_step(0.020, 4);
        assert!(t.is_violating());
        assert!((t.violation_rate() - 1.0).abs() < 1e-12);
        // Drive the EMA well under target.
        for _ in 0..100 {
            t.observe_step(0.001, 4);
        }
        assert!(!t.is_violating());
        assert!(t.has_headroom());
        assert!(t.violation_rate() < 0.05);
    }

    #[test]
    fn empty_steps_ignored() {
        let mut t = SloTracker::new(SloConfig { target_tpot_s: 0.010, margin: 0.2 });
        t.observe_step(99.0, 0);
        assert_eq!(t.observations(), 0);
        assert_eq!(t.tpot_ema(), 0.0);
    }

    #[test]
    fn zero_target_never_violates() {
        let mut t = SloTracker::new(SloConfig::default());
        t.observe_step(10.0, 1);
        assert!(!t.is_violating());
        assert!(!t.has_headroom());
        t.set_target(0.5);
        t.observe_step(10.0, 1);
        assert!(t.is_violating());
    }
}
