//! Memory-pressure degradation ladder.
//!
//! The page pool running dry forces the scheduler into recompute
//! preemption — the most expensive possible response (a victim's whole
//! prefill is redone). The ladder degrades service *gradually* before
//! that cliff, in the order the knobs are cheapest to give up:
//!
//! | level | free headroom     | action                                  |
//! |-------|-------------------|-----------------------------------------|
//! | 0     | comfortable       | none                                    |
//! | 1     | `< tighten_below` | tighten p (prune harder, steps faster)  |
//! | 2     | `< shrink_below`  | also shrink the stage-1 budget B0,      |
//! |       |                   | halve the prefill chunk span, and force |
//! |       |                   | the sparse prefill path on              |
//! | 3     | `< dense_guard`   | also raise `dense_below` so short       |
//! |       |                   | contexts skip selection entirely,       |
//! |       |                   | quarter the prefill chunk, and the      |
//! |       |                   | scheduler freezes *new* admission       |
//! |       |                   | (in-flight prefills keep draining)      |
//!
//! The chunk shrink (levels 2–3) is carried by the `degrade_level` field
//! itself — [`BudgetDirective::chunk_divisor`] maps it to a span divisor
//! the scheduler applies — so admission work and the pages a chunk
//! claims contract before the freeze cliff.
//!
//! Raising `dense_below` at level 3 is an accuracy guard, not a speed
//! knob: with p and B0 both cut, short contexts would pay the full
//! estimation error for negligible savings — running them dense keeps
//! them exact while long contexts carry the degradation.
//!
//! **Tier faults** (DESIGN.md §14) feed the same ladder: a sustained
//! rate of offload-tier read/write errors engages levels 1–2 even with
//! page headroom, because pruning harder and forcing sparse prefill are
//! exactly the knobs that touch *fewer cold pages per step* — shrinking
//! exposure to a degrading tier before pages start getting lost
//! outright. Faults alone never freeze admission (level 3 stays
//! reserved for genuine memory exhaustion); the effective rung is the
//! max of the memory rung and the fault rung.

use super::BudgetDirective;

/// Ladder thresholds (fractions of the page pool still free) and the
/// per-level knob values.
#[derive(Clone, Copy, Debug)]
pub struct PressureConfig {
    /// Below this free fraction: level 1 (tighten p).
    pub tighten_below: f64,
    /// Below this free fraction: level 2 (also shrink B0).
    pub shrink_below: f64,
    /// Below this free fraction: level 3 (dense guard + admission freeze).
    pub dense_guard_below: f64,
    /// p multiplier applied from level 1.
    pub p_scale: f32,
    /// B0 multiplier applied from level 2.
    pub budget_scale: f32,
    /// `dense_below` override applied at level 3.
    pub dense_below: usize,
    /// Smoothed tier faults/step at or above which the fault rung is 1.
    pub fault_tighten_at: f64,
    /// Smoothed tier faults/step at or above which the fault rung is 2
    /// (its ceiling — faults alone never freeze admission).
    pub fault_shrink_at: f64,
}

impl Default for PressureConfig {
    fn default() -> Self {
        PressureConfig {
            tighten_below: 0.25,
            shrink_below: 0.12,
            dense_guard_below: 0.05,
            p_scale: 0.9,
            budget_scale: 0.6,
            dense_below: 256,
            fault_tighten_at: 0.5,
            fault_shrink_at: 2.0,
        }
    }
}

impl PressureConfig {
    /// Degradation level for the observed free-page fraction.
    pub fn level(&self, free_frac: f64) -> u8 {
        if free_frac < self.dense_guard_below {
            3
        } else if free_frac < self.shrink_below {
            2
        } else if free_frac < self.tighten_below {
            1
        } else {
            0
        }
    }

    /// Fault rung for a smoothed tier-fault rate (faults/step EMA,
    /// read + write errors + lost pages). Capped at 2: degrading the
    /// pruning knobs shrinks tier exposure, but only real memory
    /// exhaustion may freeze admission.
    pub fn fault_level(&self, fault_ema: f64) -> u8 {
        if fault_ema >= self.fault_shrink_at {
            2
        } else if fault_ema >= self.fault_tighten_at {
            1
        } else {
            0
        }
    }

    /// Overlay the ladder on a policy's directive: pressure can only make
    /// the directive *tighter* (min of scales), never relax it.
    pub fn apply(&self, level: u8, d: &mut BudgetDirective) {
        d.degrade_level = level;
        if level >= 1 {
            d.p_scale = d.p_scale.min(self.p_scale);
        }
        if level >= 2 {
            d.budget_scale = d.budget_scale.min(self.budget_scale);
            // Long-prompt chunks stop paying the dense O(n²) context
            // walk: sparse prefill trades ≤ eps mass for page skipping
            // — cheaper to give up than admission (level 3's freeze).
            d.sparse_prefill_override = Some(true);
        }
        if level >= 3 {
            let floor = d.dense_below_override.unwrap_or(0).max(self.dense_below);
            d.dense_below_override = Some(floor);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_triggers_in_order() {
        let c = PressureConfig::default();
        let mut last = 0u8;
        // Free fraction draining from comfortable to exhausted: levels
        // must be monotone non-decreasing and hit every rung in order.
        let mut seen = vec![];
        for i in 0..=100 {
            let free = 1.0 - i as f64 / 100.0;
            let l = c.level(free);
            assert!(l >= last, "level dropped while pressure rose");
            if l != last || seen.is_empty() {
                seen.push(l);
            }
            last = l;
        }
        assert_eq!(seen, vec![0, 1, 2, 3]);
    }

    #[test]
    fn overlay_tightens_monotonically() {
        let c = PressureConfig::default();
        let mut prev_p = f32::INFINITY;
        let mut prev_b = f32::INFINITY;
        for level in 0..=3u8 {
            let mut d = BudgetDirective::NEUTRAL;
            c.apply(level, &mut d);
            assert_eq!(d.degrade_level, level);
            assert!(d.p_scale <= prev_p);
            assert!(d.budget_scale <= prev_b);
            prev_p = d.p_scale;
            prev_b = d.budget_scale;
            if level >= 3 {
                assert_eq!(d.dense_below_override, Some(c.dense_below));
            } else {
                assert_eq!(d.dense_below_override, None);
            }
            if level >= 2 {
                assert_eq!(d.sparse_prefill_override, Some(true));
            } else {
                assert_eq!(d.sparse_prefill_override, None);
            }
        }
    }

    #[test]
    fn fault_rung_engages_and_caps_below_freeze() {
        let c = PressureConfig::default();
        assert_eq!(c.fault_level(0.0), 0);
        assert_eq!(c.fault_level(c.fault_tighten_at), 1);
        assert_eq!(c.fault_level(c.fault_shrink_at), 2);
        // Faults alone never reach the admission-freeze rung.
        assert_eq!(c.fault_level(1e9), 2);
    }

    #[test]
    fn overlay_never_relaxes_policy() {
        let c = PressureConfig::default();
        // Policy already tighter than the ladder: pressure keeps it.
        let mut d = BudgetDirective { p_scale: 0.6, budget_scale: 0.3, ..BudgetDirective::NEUTRAL };
        c.apply(2, &mut d);
        assert_eq!(d.p_scale, 0.6);
        assert_eq!(d.budget_scale, 0.3);
    }
}
