//! Workload generation: the synthetic task suite that substitutes for
//! LongBench / RULER / GSM8K / PG-19 (DESIGN.md §3), plus arrival-process
//! generation for the serving benches.
//!
//! Tasks target the **retrieval model** (`model/retrieval.rs`): the
//! context is a stream of composite *(key, value) pair tokens*; the final
//! token is a query that either asks for the value bound to a key
//! (*NIAH* — requires focused attention on one position) or for the most
//! frequent value (*FWE* — requires diffuse attention over the whole
//! context). Both have exact ground truth at any context length, and the
//! single-token-per-pair encoding keeps the constructed model at one
//! attention layer so prefill is O(n).

use crate::util::rng::Rng;

/// A generated request: prompt tokens, query kind, ground truth.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: Vec<u32>,
    pub task: TaskKind,
    /// Expected answer token id (an answer-region token) for scoring.
    pub answer: u32,
    /// Arrival time offset in seconds (0 for batch workloads).
    pub arrival: f64,
    /// Number of output tokens to decode (serving workloads; accuracy
    /// suites use 1).
    pub max_new_tokens: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// Needle-in-a-haystack: retrieve the value bound to a unique key.
    Niah,
    /// Multi-needle: the key is bound several times to the same value.
    MultiNiah,
    /// Frequent-word extraction: output the most frequent value token.
    Fwe,
}

impl TaskKind {
    pub fn name(self) -> &'static str {
        match self {
            TaskKind::Niah => "niah",
            TaskKind::MultiNiah => "multi-niah",
            TaskKind::Fwe => "fwe",
        }
    }
}

/// Token-id layout shared with `model/retrieval.rs` and
/// `python/compile/retrieval_model.py`:
///
/// ```text
/// [0, nk*nv)                         pair tokens: pair(k,v) = k*nv + v
/// [nk*nv, nk*nv+nk)                  NIAH query tokens (one per key)
/// nk*nv + nk                         FWE query token
/// (nk*nv+nk, nk*nv+nk+nv]            answer tokens (one per value)
/// ```
#[derive(Clone, Copy, Debug)]
pub struct RetrievalVocab {
    pub n_keys: u32,
    pub n_vals: u32,
}

impl RetrievalVocab {
    pub const DEFAULT: RetrievalVocab = RetrievalVocab { n_keys: 16, n_vals: 16 };

    pub fn pair(&self, k: u32, v: u32) -> u32 {
        debug_assert!(k < self.n_keys && v < self.n_vals);
        k * self.n_vals + v
    }

    pub fn query_niah(&self, k: u32) -> u32 {
        self.n_keys * self.n_vals + k
    }

    pub fn query_fwe(&self) -> u32 {
        self.n_keys * self.n_vals + self.n_keys
    }

    pub fn answer(&self, v: u32) -> u32 {
        self.n_keys * self.n_vals + self.n_keys + 1 + v
    }

    pub fn vocab_size(&self) -> u32 {
        self.n_keys * self.n_vals + self.n_keys + 1 + self.n_vals
    }

    pub fn is_pair(&self, tok: u32) -> bool {
        tok < self.n_keys * self.n_vals
    }

    pub fn pair_key(&self, tok: u32) -> u32 {
        debug_assert!(self.is_pair(tok));
        tok / self.n_vals
    }

    pub fn pair_val(&self, tok: u32) -> u32 {
        debug_assert!(self.is_pair(tok));
        tok % self.n_vals
    }

    /// Answer-region value id of a token, if it is an answer token.
    pub fn answer_val(&self, tok: u32) -> Option<u32> {
        let base = self.n_keys * self.n_vals + self.n_keys + 1;
        if tok >= base && tok < base + self.n_vals {
            Some(tok - base)
        } else {
            None
        }
    }
}

/// Generate a NIAH request: `ctx_len` pair tokens with a unique needle
/// key bound once, query token at the end.
pub fn gen_niah(rng: &mut Rng, vocab: RetrievalVocab, ctx_len: usize) -> GenRequest {
    assert!(ctx_len >= 2);
    let needle_key = rng.below(vocab.n_keys as usize) as u32;
    let needle_val = rng.below(vocab.n_vals as usize) as u32;
    let needle_pos = rng.below(ctx_len);
    let mut prompt = Vec::with_capacity(ctx_len + 1);
    for p in 0..ctx_len {
        if p == needle_pos {
            prompt.push(vocab.pair(needle_key, needle_val));
        } else {
            let mut k = rng.below(vocab.n_keys as usize) as u32;
            while k == needle_key {
                k = rng.below(vocab.n_keys as usize) as u32;
            }
            prompt.push(vocab.pair(k, rng.below(vocab.n_vals as usize) as u32));
        }
    }
    prompt.push(vocab.query_niah(needle_key));
    GenRequest {
        prompt,
        task: TaskKind::Niah,
        answer: vocab.answer(needle_val),
        arrival: 0.0,
        max_new_tokens: 1,
    }
}

/// Multi-needle: the queried key is bound `bindings` times, all to the
/// same value (RULER multi-key flavor: selection must find *some*
/// binding).
pub fn gen_multi_niah(
    rng: &mut Rng,
    vocab: RetrievalVocab,
    ctx_len: usize,
    bindings: usize,
) -> GenRequest {
    assert!(ctx_len > bindings + 1);
    let needle_key = rng.below(vocab.n_keys as usize) as u32;
    let needle_val = rng.below(vocab.n_vals as usize) as u32;
    let mut positions = rng.sample_indices(ctx_len, bindings);
    positions.sort_unstable();
    let mut prompt = Vec::with_capacity(ctx_len + 1);
    let mut bind_i = 0;
    for p in 0..ctx_len {
        if bind_i < bindings && p == positions[bind_i] {
            prompt.push(vocab.pair(needle_key, needle_val));
            bind_i += 1;
        } else {
            let mut k = rng.below(vocab.n_keys as usize) as u32;
            while k == needle_key {
                k = rng.below(vocab.n_keys as usize) as u32;
            }
            prompt.push(vocab.pair(k, rng.below(vocab.n_vals as usize) as u32));
        }
    }
    prompt.push(vocab.query_niah(needle_key));
    GenRequest {
        prompt,
        task: TaskKind::MultiNiah,
        answer: vocab.answer(needle_val),
        arrival: 0.0,
        max_new_tokens: 1,
    }
}

/// FWE: one value id appears `boost`× more often than baseline; the query
/// asks for the most frequent value. Needs *diffuse* attention: a sparse
/// method that truncates most of the context mis-estimates frequencies.
pub fn gen_fwe(rng: &mut Rng, vocab: RetrievalVocab, ctx_len: usize, boost: f64) -> GenRequest {
    let hot_val = rng.below(vocab.n_vals as usize) as u32;
    let mut counts = vec![0usize; vocab.n_vals as usize];
    let mut prompt = Vec::with_capacity(ctx_len + 1);
    for _ in 0..ctx_len {
        let k = rng.below(vocab.n_keys as usize) as u32;
        let v = if rng.chance(boost / (boost + vocab.n_vals as f64)) {
            hot_val
        } else {
            rng.below(vocab.n_vals as usize) as u32
        };
        counts[v as usize] += 1;
        prompt.push(vocab.pair(k, v));
    }
    let argmax = counts
        .iter()
        .enumerate()
        .max_by_key(|(_, &c)| c)
        .map(|(i, _)| i as u32)
        .unwrap();
    prompt.push(vocab.query_fwe());
    GenRequest {
        prompt,
        task: TaskKind::Fwe,
        answer: vocab.answer(argmax),
        arrival: 0.0,
        max_new_tokens: 1,
    }
}

/// A batch workload mixing the three tasks (the LongBench/RULER analog
/// suite).
pub fn gen_suite(
    seed: u64,
    vocab: RetrievalVocab,
    ctx_len: usize,
    n_per_task: usize,
) -> Vec<GenRequest> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for _ in 0..n_per_task {
        out.push(gen_niah(&mut rng, vocab, ctx_len));
        out.push(gen_multi_niah(&mut rng, vocab, ctx_len, 4));
        out.push(gen_fwe(&mut rng, vocab, ctx_len, 8.0));
    }
    out
}

/// Attach Poisson arrivals at `rate` req/s to a batch of requests.
pub fn poissonize(reqs: &mut [GenRequest], seed: u64, rate: f64) {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    for r in reqs.iter_mut() {
        t += rng.exp(rate);
        r.arrival = t;
    }
}

/// Load a token corpus written by `python/compile/corpus.py`
/// (`artifacts/corpus_eval.bin`: raw u8 token ids) for perplexity evals.
pub fn load_corpus(path: &str) -> std::io::Result<Vec<u32>> {
    let bytes = std::fs::read(path)?;
    Ok(bytes.into_iter().map(|b| b as u32).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    const V: RetrievalVocab = RetrievalVocab::DEFAULT;

    #[test]
    fn vocab_layout_disjoint() {
        assert_eq!(V.vocab_size(), 16 * 16 + 16 + 1 + 16);
        assert!(V.is_pair(V.pair(15, 15)));
        assert!(!V.is_pair(V.query_niah(0)));
        assert_eq!(V.answer_val(V.answer(7)), Some(7));
        assert_eq!(V.answer_val(V.query_fwe()), None);
        assert_eq!(V.pair_key(V.pair(3, 9)), 3);
        assert_eq!(V.pair_val(V.pair(3, 9)), 9);
    }

    #[test]
    fn niah_structure() {
        let mut r = Rng::new(1);
        let g = gen_niah(&mut r, V, 256);
        assert_eq!(g.prompt.len(), 257);
        let qtok = g.prompt[256];
        let qkey = qtok - V.n_keys * V.n_vals;
        // The needle key appears exactly once among pair tokens.
        let mut found = None;
        for p in 0..256 {
            let tok = g.prompt[p];
            assert!(V.is_pair(tok));
            if V.pair_key(tok) == qkey {
                assert!(found.is_none(), "needle key bound twice");
                found = Some(V.pair_val(tok));
            }
        }
        assert_eq!(V.answer(found.unwrap()), g.answer);
    }

    #[test]
    fn multi_niah_consistent_value() {
        let mut r = Rng::new(2);
        let g = gen_multi_niah(&mut r, V, 512, 4);
        let qkey = g.prompt[512] - V.n_keys * V.n_vals;
        let mut bindings = 0;
        for p in 0..512 {
            if V.pair_key(g.prompt[p]) == qkey {
                assert_eq!(V.answer(V.pair_val(g.prompt[p])), g.answer);
                bindings += 1;
            }
        }
        assert_eq!(bindings, 4);
    }

    #[test]
    fn fwe_answer_is_mode() {
        let mut r = Rng::new(3);
        let g = gen_fwe(&mut r, V, 2048, 8.0);
        let mut counts = vec![0usize; V.n_vals as usize];
        for p in 0..2048 {
            counts[V.pair_val(g.prompt[p]) as usize] += 1;
        }
        let mode = counts.iter().enumerate().max_by_key(|(_, &c)| c).unwrap().0 as u32;
        assert_eq!(g.answer, V.answer(mode));
        let sorted = {
            let mut c = counts.clone();
            c.sort_unstable_by(|a, b| b.cmp(a));
            c
        };
        assert!(sorted[0] > sorted[1] * 2, "{sorted:?}");
    }

    #[test]
    fn suite_and_arrivals() {
        let mut reqs = gen_suite(7, V, 128, 3);
        assert_eq!(reqs.len(), 9);
        poissonize(&mut reqs, 8, 100.0);
        for w in reqs.windows(2) {
            assert!(w[1].arrival > w[0].arrival);
        }
    }

    #[test]
    fn deterministic_generation() {
        let a = gen_suite(42, V, 64, 2);
        let b = gen_suite(42, V, 64, 2);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.answer, y.answer);
        }
    }
}
