//! Dense tensor substrate: row-major f32 tensors, fp16 bit conversion,
//! and the quantized K-cache representations (INT2/4/8) from §4.2 of the
//! paper.
//!
//! The free functions below (`dot`, `axpy`, `gemv`, `softmax_inplace`,
//! `rmsnorm`) are thin dispatchers over the runtime-selected kernel
//! table in [`kernels`] — `TWILIGHT_KERNEL={auto,scalar,avx2,neon}`
//! picks the backend; `scalar` reproduces the historical loops
//! bit-for-bit (see `kernels/` module docs for the exactness contract).

pub mod fp16;
pub mod kernels;
pub mod quant;

/// A row-major f32 tensor with explicit shape. The compute kernels in
/// `attention/` take raw slices for speed; `Tensor` is the bookkeeping
/// type used at module boundaries (weights, activations, literals).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { data: vec![0.0; shape.iter().product()], shape: shape.to_vec() }
    }

    pub fn from_vec(data: Vec<f32>, shape: &[usize]) -> Tensor {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        Tensor { data, shape: shape.to_vec() }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.ndim(), 2);
        let d = self.shape[1];
        &self.data[i * d..(i + 1) * d]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        assert_eq!(self.ndim(), 2);
        let d = self.shape[1];
        &mut self.data[i * d..(i + 1) * d]
    }

    /// Reshape in place (numel must match).
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(self.numel(), shape.iter().product::<usize>());
        self.shape = shape.to_vec();
        self
    }

    /// Max |a - b| between two tensors of identical shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }
}

/// y = W x + b for row-major `w: [out, inp]`. The MLP/QKV hot path.
/// Rows contract through the active backend's `dot` (fetched once).
pub fn gemv(w: &[f32], x: &[f32], bias: Option<&[f32]>, out: &mut [f32]) {
    let inp = x.len();
    debug_assert_eq!(w.len(), out.len() * inp);
    let kn = kernels::active();
    for (o, row) in out.iter_mut().zip(w.chunks_exact(inp)) {
        *o = (kn.dot)(row, x);
    }
    if let Some(b) = bias {
        for (o, bi) in out.iter_mut().zip(b) {
            *o += bi;
        }
    }
}

/// Dot product via the active kernel backend (scalar reference: 4
/// independent partial sums over exact chunks, in `kernels/scalar.rs`).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    (kernels::active().dot)(a, b)
}

/// `out += s * x` (axpy), used by attention value accumulation.
#[inline]
pub fn axpy(s: f32, x: &[f32], out: &mut [f32]) {
    (kernels::active().axpy)(s, x, out)
}

/// Numerically-stable in-place softmax; returns the max logit (useful for
/// streaming variants and tests). Bit-identical across kernel backends.
pub fn softmax_inplace(xs: &mut [f32]) -> f32 {
    (kernels::active().softmax)(xs)
}

/// RMSNorm: `x * w / rms(x)`.
pub fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    (kernels::active().rmsnorm)(x, w, eps, out)
}

/// Rotary position embedding applied in pairs `(x[2i], x[2i+1])`,
/// matching the python/compile/model.py convention.
pub fn rope_inplace(x: &mut [f32], pos: usize, theta: f32) {
    let d = x.len();
    let half = d / 2;
    for i in 0..half {
        let freq = theta.powf(-2.0 * i as f32 / d as f32);
        let ang = pos as f32 * freq;
        let (sin, cos) = ang.sin_cos();
        let a = x[2 * i];
        let b = x[2 * i + 1];
        x[2 * i] = a * cos - b * sin;
        x[2 * i + 1] = a * sin + b * cos;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_rows() {
        let t = Tensor::from_vec(vec![1., 2., 3., 4., 5., 6.], &[2, 3]);
        assert_eq!(t.row(0), &[1., 2., 3.]);
        assert_eq!(t.row(1), &[4., 5., 6.]);
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..131).map(|i| i as f32 * 0.1).collect();
        let b: Vec<f32> = (0..131).map(|i| (130 - i) as f32 * 0.01).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn gemv_identity() {
        let n = 5;
        let mut w = vec![0.0; n * n];
        for i in 0..n {
            w[i * n + i] = 1.0;
        }
        let x = vec![1., 2., 3., 4., 5.];
        let mut y = vec![0.0; n];
        gemv(&w, &x, None, &mut y);
        assert_eq!(y, x);
        gemv(&w, &x, Some(&[1.0; 5]), &mut y);
        assert_eq!(y, vec![2., 3., 4., 5., 6.]);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut x = vec![1.0, 2.0, 3.0, 1000.0];
        softmax_inplace(&mut x);
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(x[3] > 0.999);
    }

    #[test]
    fn softmax_uniform() {
        let mut x = vec![0.5; 8];
        softmax_inplace(&mut x);
        for v in x {
            assert!((v - 0.125).abs() < 1e-6);
        }
    }

    #[test]
    fn rmsnorm_unit() {
        let x = vec![3.0, 4.0];
        let w = vec![1.0, 1.0];
        let mut out = vec![0.0; 2];
        rmsnorm(&x, &w, 0.0, &mut out);
        // rms = sqrt((9+16)/2) = sqrt(12.5)
        let rms = 12.5f32.sqrt();
        assert!((out[0] - 3.0 / rms).abs() < 1e-6);
        assert!((out[1] - 4.0 / rms).abs() < 1e-6);
    }

    #[test]
    fn rope_preserves_norm_and_pos0_identity() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0];
        let orig = x.clone();
        rope_inplace(&mut x, 0, 10000.0);
        assert_eq!(x, orig);
        rope_inplace(&mut x, 17, 10000.0);
        let n0: f32 = orig.iter().map(|v| v * v).sum();
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-4);
        assert!(x != orig);
    }

    #[test]
    fn rope_relative_dot_invariance() {
        // q at pos p and k at pos p+delta: dot depends only on delta.
        let q0 = vec![0.3, -0.2, 0.9, 0.1];
        let k0 = vec![-0.5, 0.4, 0.2, 0.8];
        let dot_at = |p: usize, delta: usize| {
            let mut q = q0.clone();
            let mut k = k0.clone();
            rope_inplace(&mut q, p + delta, 10000.0);
            rope_inplace(&mut k, p, 10000.0);
            dot(&q, &k)
        };
        assert!((dot_at(0, 5) - dot_at(100, 5)).abs() < 1e-3);
    }
}
