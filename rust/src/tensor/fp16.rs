//! IEEE-754 binary16 conversion (bit-level, no `half` crate). The FP16 K
//! cache is the paper's baseline precision: we store it as `u16` words and
//! convert on load, which also makes byte-traffic accounting exact for the
//! memory-bound cost model in `sim/`.

/// Convert f32 -> f16 bits (round-to-nearest-even).
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x7F_FFFF;

    if exp == 0xFF {
        // Inf/NaN
        return sign | 0x7C00 | if mant != 0 { 0x200 } else { 0 };
    }
    // Re-bias: f32 exp-127 -> f16 exp-15
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if unbiased >= -14 {
        // Normal f16.
        let mut e = (unbiased + 15) as u32;
        let mut m = mant >> 13;
        // Round to nearest even on the 13 dropped bits.
        let rem = mant & 0x1FFF;
        if rem > 0x1000 || (rem == 0x1000 && (m & 1) == 1) {
            m += 1;
            if m == 0x400 {
                m = 0;
                e += 1;
                if e >= 31 {
                    return sign | 0x7C00;
                }
            }
        }
        return sign | ((e as u16) << 10) | (m as u16);
    }
    if unbiased >= -24 {
        // Subnormal f16.
        // value = 1.mant * 2^unbiased = m16 * 2^-24 with m16 = full >> shift,
        // full the 24-bit significand and shift = -1 - unbiased (14..=23).
        let full = mant | 0x80_0000; // implicit leading 1
        let shift = (-1 - unbiased) as u32;
        let m = full >> shift;
        let rem = full & ((1 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut m16 = m as u16;
        if rem > half || (rem == half && (m16 & 1) == 1) {
            m16 += 1;
        }
        return sign | m16;
    }
    sign // underflow to zero
}

/// Convert f16 bits -> f32.
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x3FF) as u32;
    let bits = if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // Subnormal: normalize.
            let mut e = -14i32;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x3FF;
            sign | (((e + 127) as u32) << 23) | (m << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Quantize a slice to fp16 storage.
pub fn encode(xs: &[f32]) -> Vec<u16> {
    xs.iter().map(|&x| f32_to_f16(x)).collect()
}

/// Batch f16 → f32 through the active kernel backend (F16C/NEON wide
/// converts where available; value-exact in every backend). Lengths
/// must match — use [`decode_into`] for the forgiving zip semantics.
pub fn f16_to_f32_slice(hs: &[u16], out: &mut [f32]) {
    assert_eq!(hs.len(), out.len());
    (super::kernels::active().f16_slice)(hs, out)
}

/// Decode fp16 storage back into f32 (stops at the shorter slice).
pub fn decode_into(hs: &[u16], out: &mut [f32]) {
    let n = hs.len().min(out.len());
    (super::kernels::active().f16_slice)(&hs[..n], &mut out[..n])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_values() {
        for &v in &[0.0f32, 1.0, -1.0, 0.5, 2.0, -0.25, 1024.0] {
            assert_eq!(f16_to_f32(f32_to_f16(v)), v, "v={v}");
        }
    }

    #[test]
    fn roundtrip_error_bounded() {
        // Relative error of f16 is <= 2^-11 for normals.
        let mut x = -8.0f32;
        while x < 8.0 {
            let r = f16_to_f32(f32_to_f16(x));
            if x.abs() > 1e-4 {
                assert!(((r - x) / x).abs() < 1.0 / 1024.0, "x={x} r={r}");
            }
            x += 0.0137;
        }
    }

    #[test]
    fn specials() {
        assert_eq!(f16_to_f32(f32_to_f16(f32::INFINITY)), f32::INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        assert_eq!(f16_to_f32(f32_to_f16(1e9)), f32::INFINITY); // overflow
        assert_eq!(f16_to_f32(f32_to_f16(1e-10)), 0.0); // underflow
    }

    #[test]
    fn subnormals() {
        let tiny = 3.0e-5f32; // subnormal range for f16 is < 6.1e-5
        let r = f16_to_f32(f32_to_f16(tiny));
        assert!((r - tiny).abs() / tiny < 0.05, "tiny={tiny} r={r}");
    }

    #[test]
    fn encode_decode_slice() {
        let xs: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.37).collect();
        let hs = encode(&xs);
        let mut out = vec![0.0; 100];
        decode_into(&hs, &mut out);
        for (a, b) in xs.iter().zip(&out) {
            assert!((a - b).abs() <= a.abs() * 0.001 + 1e-3);
        }
    }

    /// Independent f64 reference for an f16 bit pattern: subnormals are
    /// `mant · 2⁻²⁴`, normals `(1024 + mant)/1024 · 2^(exp−15)` — both
    /// exactly representable in f64, so `as f32` is the true value.
    fn f16_ref(h: u16) -> f32 {
        let sign = if h & 0x8000 != 0 { -1.0f64 } else { 1.0 };
        let exp = ((h >> 10) & 0x1F) as i32;
        let mant = (h & 0x3FF) as f64;
        let v = if exp == 0 {
            mant * (-24f64).exp2()
        } else if exp == 0x1F {
            f64::INFINITY // mant != 0 (NaN) is handled by the caller
        } else {
            (1024.0 + mant) / 1024.0 * f64::from(exp - 15).exp2()
        };
        (sign * v) as f32
    }

    #[test]
    fn f16_to_f32_exhaustive_all_bit_patterns() {
        // Every one of the 65536 half bit patterns, pinned against the
        // independent reference: subnormals, both zeros, both infinities,
        // and the full NaN space.
        for h in 0..=u16::MAX {
            let got = f16_to_f32(h);
            if (h >> 10) & 0x1F == 0x1F && h & 0x3FF != 0 {
                assert!(got.is_nan(), "h={h:#06x} should be NaN, got {got}");
            } else {
                let want = f16_ref(h);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "h={h:#06x} got={got:e} want={want:e}"
                );
            }
        }
    }

    #[test]
    fn f32_to_f16_roundtrips_every_half_exactly() {
        // f16 -> f32 is exact, so converting back must return the very
        // same bits for every non-NaN pattern (NaNs only need to stay
        // NaN with the sign and quiet bit possibly normalized).
        for h in 0..=u16::MAX {
            let f = f16_to_f32(h);
            if f.is_nan() {
                let back = f32_to_f16(f);
                assert!((back >> 10) & 0x1F == 0x1F && back & 0x3FF != 0, "h={h:#06x}");
            } else {
                assert_eq!(f32_to_f16(f), h, "h={h:#06x} f={f:e}");
            }
        }
    }

    #[test]
    fn f32_to_f16_round_to_nearest_even() {
        // Halfway cases must round to the even mantissa, in both the
        // normal and subnormal ranges.
        let ulp = (-10f32).exp2(); // f16 mantissa step at 1.0
        // 1 + ulp/2 is exactly halfway between 1.0 and 1+ulp -> even (1.0).
        assert_eq!(f32_to_f16(1.0 + ulp / 2.0), f32_to_f16(1.0));
        // 1 + 3·ulp/2 is halfway between 1+ulp and 1+2·ulp -> even (1+2·ulp).
        assert_eq!(f32_to_f16(1.0 + 1.5 * ulp), f32_to_f16(1.0 + 2.0 * ulp));
        // Just above halfway rounds up.
        assert_eq!(f32_to_f16(1.0 + ulp / 2.0 + ulp / 8.0), f32_to_f16(1.0 + ulp));
        // Subnormal range: smallest subnormal is 2^-24.
        let sub = (-24f32).exp2();
        // 2^-25 is halfway between 0 and 2^-24 -> even (0).
        assert_eq!(f32_to_f16(sub / 2.0), 0);
        // 3·2^-25 is halfway between 2^-24 and 2^-23 -> even (m16 = 2).
        assert_eq!(f32_to_f16(1.5 * sub), 2);
        // Overflow boundary: values at or above 65520 round to inf,
        // below it to f16::MAX (65504).
        assert_eq!(f32_to_f16(65519.9), f32_to_f16(65504.0));
        assert_eq!(f32_to_f16(65520.0), f32_to_f16(f32::INFINITY));
    }

    #[test]
    fn f16_slice_matches_scalar_convert() {
        // The batch path must agree with per-element conversion for
        // every finite pattern and all remainder-tail lengths.
        let hs: Vec<u16> = (0..=u16::MAX)
            .filter(|h| !((h >> 10) & 0x1F == 0x1F && h & 0x3FF != 0))
            .collect();
        let mut out = vec![0.0f32; hs.len()];
        f16_to_f32_slice(&hs, &mut out);
        for (&h, &o) in hs.iter().zip(&out) {
            assert_eq!(o.to_bits(), f16_to_f32(h).to_bits(), "h={h:#06x}");
        }
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31] {
            let mut small = vec![0.0f32; n];
            f16_to_f32_slice(&hs[100..100 + n], &mut small);
            for (i, &o) in small.iter().enumerate() {
                assert_eq!(o.to_bits(), f16_to_f32(hs[100 + i]).to_bits());
            }
        }
    }
}
