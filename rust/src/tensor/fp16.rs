//! IEEE-754 binary16 conversion (bit-level, no `half` crate). The FP16 K
//! cache is the paper's baseline precision: we store it as `u16` words and
//! convert on load, which also makes byte-traffic accounting exact for the
//! memory-bound cost model in `sim/`.

/// Convert f32 -> f16 bits (round-to-nearest-even).
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x7F_FFFF;

    if exp == 0xFF {
        // Inf/NaN
        return sign | 0x7C00 | if mant != 0 { 0x200 } else { 0 };
    }
    // Re-bias: f32 exp-127 -> f16 exp-15
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if unbiased >= -14 {
        // Normal f16.
        let mut e = (unbiased + 15) as u32;
        let mut m = mant >> 13;
        // Round to nearest even on the 13 dropped bits.
        let rem = mant & 0x1FFF;
        if rem > 0x1000 || (rem == 0x1000 && (m & 1) == 1) {
            m += 1;
            if m == 0x400 {
                m = 0;
                e += 1;
                if e >= 31 {
                    return sign | 0x7C00;
                }
            }
        }
        return sign | ((e as u16) << 10) | (m as u16);
    }
    if unbiased >= -24 {
        // Subnormal f16.
        // value = 1.mant * 2^unbiased = m16 * 2^-24 with m16 = full >> shift,
        // full the 24-bit significand and shift = -1 - unbiased (14..=23).
        let full = mant | 0x80_0000; // implicit leading 1
        let shift = (-1 - unbiased) as u32;
        let m = full >> shift;
        let rem = full & ((1 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut m16 = m as u16;
        if rem > half || (rem == half && (m16 & 1) == 1) {
            m16 += 1;
        }
        return sign | m16;
    }
    sign // underflow to zero
}

/// Convert f16 bits -> f32.
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x3FF) as u32;
    let bits = if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // Subnormal: normalize.
            let mut e = -14i32;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x3FF;
            sign | (((e + 127) as u32) << 23) | (m << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Quantize a slice to fp16 storage.
pub fn encode(xs: &[f32]) -> Vec<u16> {
    xs.iter().map(|&x| f32_to_f16(x)).collect()
}

/// Decode fp16 storage back into f32.
pub fn decode_into(hs: &[u16], out: &mut [f32]) {
    for (o, &h) in out.iter_mut().zip(hs) {
        *o = f16_to_f32(h);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_values() {
        for &v in &[0.0f32, 1.0, -1.0, 0.5, 2.0, -0.25, 1024.0] {
            assert_eq!(f16_to_f32(f32_to_f16(v)), v, "v={v}");
        }
    }

    #[test]
    fn roundtrip_error_bounded() {
        // Relative error of f16 is <= 2^-11 for normals.
        let mut x = -8.0f32;
        while x < 8.0 {
            let r = f16_to_f32(f32_to_f16(x));
            if x.abs() > 1e-4 {
                assert!(((r - x) / x).abs() < 1.0 / 1024.0, "x={x} r={r}");
            }
            x += 0.0137;
        }
    }

    #[test]
    fn specials() {
        assert_eq!(f16_to_f32(f32_to_f16(f32::INFINITY)), f32::INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        assert_eq!(f16_to_f32(f32_to_f16(1e9)), f32::INFINITY); // overflow
        assert_eq!(f16_to_f32(f32_to_f16(1e-10)), 0.0); // underflow
    }

    #[test]
    fn subnormals() {
        let tiny = 3.0e-5f32; // subnormal range for f16 is < 6.1e-5
        let r = f16_to_f32(f32_to_f16(tiny));
        assert!((r - tiny).abs() / tiny < 0.05, "tiny={tiny} r={r}");
    }

    #[test]
    fn encode_decode_slice() {
        let xs: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.37).collect();
        let hs = encode(&xs);
        let mut out = vec![0.0; 100];
        decode_into(&hs, &mut out);
        for (a, b) in xs.iter().zip(&out) {
            assert!((a - b).abs() <= a.abs() * 0.001 + 1e-3);
        }
    }
}
