//! NEON backend (aarch64; NEON is baseline on AArch64 but detection is
//! still consulted before this table is handed out).
//!
//! Same exactness story as the AVX2 backend: reductions are 4 lanes
//! wide with fused multiply-add and eps-bounded against scalar, while
//! `dot_strict` / `dot_f16` share one accumulation structure (single
//! 4-wide accumulator, `vaddvq_f32` horizontal sum, sequential scalar
//! tail) so widened-f16 and packed-f16 dots agree bitwise. f16→f32
//! conversion stays the scalar bit-twiddle (`fp16::f16_to_f32`) — the
//! stable-toolchain `std::arch` surface has no f16 vector type — so the
//! conversion entries are value-exact by construction; the fp16 dot
//! still vectorizes its multiply-accumulate over a widened stack tile.
//!
//! `unsafe` discipline matches `avx2.rs`: private
//! `#[target_feature(enable = "neon")] unsafe fn *_impl` bodies behind
//! safe wrappers that are only reachable through a detection-gated table.

use super::{scalar, Backend, Kernels};
use crate::tensor::fp16::f16_to_f32;
use core::arch::aarch64::*;

pub static TABLE: Kernels = Kernels {
    backend: Backend::Neon,
    dot,
    dot_strict,
    axpy,
    dot_q_i8,
    dot_q_i4,
    dot_q_i2,
    dot_f16,
    unpack_i8,
    unpack_i4,
    // Value-exact scalar widenings kept for the cold/awkward shapes
    // (INT2 crumbs; f16 conversion has no stable NEON vector form).
    unpack_i2: scalar::unpack_i2,
    unpack_f16: scalar::unpack_f16,
    f16_slice: scalar::f16_slice,
    softmax,
    rmsnorm,
};

// SAFETY (applies to every wrapper below): the `*_impl` functions
// require NEON; this table is only reachable via
// `kernels::table(Backend::Neon)`, which returns `None` unless
// `is_aarch64_feature_detected!("neon")` held.

fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    unsafe { dot_impl(a, b) }
}

fn dot_strict(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    unsafe { dot_strict_impl(a, b) }
}

fn axpy(s: f32, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    unsafe { axpy_impl(s, x, out) }
}

fn dot_q_i8(q: &[f32], packed: &[u8], zero: f32, scale: f32) -> f32 {
    debug_assert!(packed.len() >= q.len());
    unsafe { dot_q_i8_impl(q, packed, zero, scale) }
}

fn dot_q_i4(q: &[f32], packed: &[u8], zero: f32, scale: f32) -> f32 {
    debug_assert!(packed.len() >= q.len().div_ceil(2));
    unsafe { dot_q_i4_impl(q, packed, zero, scale) }
}

fn dot_q_i2(q: &[f32], packed: &[u8], zero: f32, scale: f32) -> f32 {
    debug_assert!(packed.len() >= q.len().div_ceil(4));
    unsafe { dot_q_i2_impl(q, packed, zero, scale) }
}

fn dot_f16(q: &[f32], packed: &[u8]) -> f32 {
    debug_assert_eq!(packed.len(), 2 * q.len());
    unsafe { dot_f16_impl(q, packed) }
}

fn unpack_i8(bytes: &[u8], out: &mut [f32]) {
    debug_assert_eq!(bytes.len(), out.len());
    unsafe { unpack_i8_impl(bytes, out) }
}

fn unpack_i4(bytes: &[u8], out: &mut [f32]) {
    debug_assert_eq!(bytes.len() * 2, out.len());
    unsafe { unpack_i4_impl(bytes, out) }
}

fn softmax(xs: &mut [f32]) -> f32 {
    if xs.is_empty() {
        return f32::NEG_INFINITY;
    }
    unsafe { softmax_impl(xs) }
}

fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), w.len());
    unsafe { rmsnorm_impl(x, w, eps, out) }
}

/// Throughput dot: 4 independent 4-lane FMA accumulators (16 elements
/// per iteration), a 4-wide cleanup loop, and a scalar tail.
#[target_feature(enable = "neon")]
unsafe fn dot_impl(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut acc2 = vdupq_n_f32(0.0);
    let mut acc3 = vdupq_n_f32(0.0);
    let blocks = n / 16;
    for i in 0..blocks {
        let j = i * 16;
        acc0 = vfmaq_f32(acc0, vld1q_f32(pa.add(j)), vld1q_f32(pb.add(j)));
        acc1 = vfmaq_f32(acc1, vld1q_f32(pa.add(j + 4)), vld1q_f32(pb.add(j + 4)));
        acc2 = vfmaq_f32(acc2, vld1q_f32(pa.add(j + 8)), vld1q_f32(pb.add(j + 8)));
        acc3 = vfmaq_f32(acc3, vld1q_f32(pa.add(j + 12)), vld1q_f32(pb.add(j + 12)));
    }
    let mut j = blocks * 16;
    while j + 4 <= n {
        acc0 = vfmaq_f32(acc0, vld1q_f32(pa.add(j)), vld1q_f32(pb.add(j)));
        j += 4;
    }
    let mut s = vaddvq_f32(vaddq_f32(vaddq_f32(acc0, acc1), vaddq_f32(acc2, acc3)));
    while j < n {
        s += a[j] * b[j];
        j += 1;
    }
    s
}

/// Single-accumulator dot, structurally paired with `dot_f16_impl`.
#[target_feature(enable = "neon")]
unsafe fn dot_strict_impl(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let mut acc = vdupq_n_f32(0.0);
    let chunks = n / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc = vfmaq_f32(acc, vld1q_f32(pa.add(j)), vld1q_f32(pb.add(j)));
    }
    let mut s = vaddvq_f32(acc);
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

#[target_feature(enable = "neon")]
unsafe fn axpy_impl(s: f32, x: &[f32], out: &mut [f32]) {
    let n = x.len();
    let sv = vdupq_n_f32(s);
    let px = x.as_ptr();
    let po = out.as_mut_ptr();
    let chunks = n / 4;
    for i in 0..chunks {
        let j = i * 4;
        vst1q_f32(po.add(j), vfmaq_f32(vld1q_f32(po.add(j)), sv, vld1q_f32(px.add(j))));
    }
    for j in chunks * 4..n {
        out[j] += s * x[j];
    }
}

/// Widen 8 unsigned codes (one `vld1_u8`) to two f32 quads.
#[target_feature(enable = "neon")]
unsafe fn widen8(b: uint8x8_t) -> (float32x4_t, float32x4_t) {
    let w = vmovl_u8(b);
    (
        vcvtq_f32_u32(vmovl_u16(vget_low_u16(w))),
        vcvtq_f32_u32(vmovl_u16(vget_high_u16(w))),
    )
}

#[target_feature(enable = "neon")]
unsafe fn dot_q_i8_impl(q: &[f32], packed: &[u8], zero: f32, scale: f32) -> f32 {
    let n = q.len();
    let pq = q.as_ptr();
    let pc = packed.as_ptr();
    let mut code_acc = vdupq_n_f32(0.0);
    let mut qsum_acc = vdupq_n_f32(0.0);
    let chunks = n / 8;
    for i in 0..chunks {
        let j = i * 8;
        let (c0, c1) = widen8(vld1_u8(pc.add(j)));
        let q0 = vld1q_f32(pq.add(j));
        let q1 = vld1q_f32(pq.add(j + 4));
        code_acc = vfmaq_f32(code_acc, q0, c0);
        code_acc = vfmaq_f32(code_acc, q1, c1);
        qsum_acc = vaddq_f32(qsum_acc, vaddq_f32(q0, q1));
    }
    let mut code_dot = vaddvq_f32(code_acc);
    let mut qsum = vaddvq_f32(qsum_acc);
    for j in chunks * 8..n {
        code_dot += q[j] * packed[j] as f32;
        qsum += q[j];
    }
    zero * qsum + scale * code_dot
}

#[target_feature(enable = "neon")]
unsafe fn dot_q_i4_impl(q: &[f32], packed: &[u8], zero: f32, scale: f32) -> f32 {
    let n = q.len();
    let pq = q.as_ptr();
    let pc = packed.as_ptr();
    let nib = vdup_n_u8(0x0F);
    let mut code_acc = vdupq_n_f32(0.0);
    let mut qsum_acc = vdupq_n_f32(0.0);
    // 8 packed bytes = 16 codes per block, restored to element order
    // (low nibble first) by zipping the masked halves.
    let blocks = n / 16;
    for blk in 0..blocks {
        let bytes = vld1_u8(pc.add(blk * 8));
        let lo = vand_u8(bytes, nib);
        let hi = vshr_n_u8::<4>(bytes);
        let il0 = vzip1_u8(lo, hi); // codes 0..8
        let il1 = vzip2_u8(lo, hi); // codes 8..16
        for (k, il) in [il0, il1].into_iter().enumerate() {
            let (c0, c1) = widen8(il);
            let j = blk * 16 + k * 8;
            let q0 = vld1q_f32(pq.add(j));
            let q1 = vld1q_f32(pq.add(j + 4));
            code_acc = vfmaq_f32(code_acc, q0, c0);
            code_acc = vfmaq_f32(code_acc, q1, c1);
            qsum_acc = vaddq_f32(qsum_acc, vaddq_f32(q0, q1));
        }
    }
    let mut code_dot = vaddvq_f32(code_acc);
    let mut qsum = vaddvq_f32(qsum_acc);
    for i in blocks * 16..n {
        let byte = packed[i / 2];
        let code = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
        code_dot += q[i] * code as f32;
        qsum += q[i];
    }
    zero * qsum + scale * code_dot
}

#[target_feature(enable = "neon")]
unsafe fn dot_q_i2_impl(q: &[f32], packed: &[u8], zero: f32, scale: f32) -> f32 {
    let n = q.len();
    let pq = q.as_ptr();
    let mut code_acc = vdupq_n_f32(0.0);
    let mut qsum_acc = vdupq_n_f32(0.0);
    // Crumb interleave is branchy; widen 16 codes (4 bytes) to a stack
    // tile scalar-side, keep the multiply-accumulate vectorized.
    let mut tile = [0.0f32; 16];
    let blocks = n / 16;
    for blk in 0..blocks {
        for (p, &byte) in packed[blk * 4..blk * 4 + 4].iter().enumerate() {
            tile[4 * p] = (byte & 0x03) as f32;
            tile[4 * p + 1] = ((byte >> 2) & 0x03) as f32;
            tile[4 * p + 2] = ((byte >> 4) & 0x03) as f32;
            tile[4 * p + 3] = (byte >> 6) as f32;
        }
        for k in 0..4 {
            let codes = vld1q_f32(tile.as_ptr().add(k * 4));
            let qv = vld1q_f32(pq.add(blk * 16 + k * 4));
            code_acc = vfmaq_f32(code_acc, qv, codes);
            qsum_acc = vaddq_f32(qsum_acc, qv);
        }
    }
    let mut code_dot = vaddvq_f32(code_acc);
    let mut qsum = vaddvq_f32(qsum_acc);
    for i in blocks * 16..n {
        let code = (packed[i / 4] >> ((i % 4) * 2)) & 0x03;
        code_dot += q[i] * code as f32;
        qsum += q[i];
    }
    zero * qsum + scale * code_dot
}

/// Fused fp16 dot: scalar-exact conversion into a 4-wide stack tile,
/// FMA into a single accumulator — the structure `dot_strict_impl`
/// mirrors (so widened and packed fp16 paths agree bitwise).
#[target_feature(enable = "neon")]
unsafe fn dot_f16_impl(q: &[f32], packed: &[u8]) -> f32 {
    let n = q.len();
    let pq = q.as_ptr();
    let mut acc = vdupq_n_f32(0.0);
    let mut tile = [0.0f32; 4];
    let chunks = n / 4;
    for i in 0..chunks {
        let j = i * 4;
        for (t, k) in tile.iter_mut().zip(j..j + 4) {
            *t = f16_to_f32(u16::from_le_bytes([packed[2 * k], packed[2 * k + 1]]));
        }
        acc = vfmaq_f32(acc, vld1q_f32(pq.add(j)), vld1q_f32(tile.as_ptr()));
    }
    let mut s = vaddvq_f32(acc);
    for i in chunks * 4..n {
        let h = u16::from_le_bytes([packed[2 * i], packed[2 * i + 1]]);
        s += q[i] * f16_to_f32(h);
    }
    s
}

#[target_feature(enable = "neon")]
unsafe fn unpack_i8_impl(bytes: &[u8], out: &mut [f32]) {
    let n = out.len();
    let pb = bytes.as_ptr();
    let po = out.as_mut_ptr();
    let chunks = n / 8;
    for i in 0..chunks {
        let j = i * 8;
        let (c0, c1) = widen8(vld1_u8(pb.add(j)));
        vst1q_f32(po.add(j), c0);
        vst1q_f32(po.add(j + 4), c1);
    }
    for j in chunks * 8..n {
        out[j] = bytes[j] as f32;
    }
}

#[target_feature(enable = "neon")]
unsafe fn unpack_i4_impl(bytes: &[u8], out: &mut [f32]) {
    let n = out.len(); // even; bytes.len() == n / 2
    let pb = bytes.as_ptr();
    let po = out.as_mut_ptr();
    let nib = vdup_n_u8(0x0F);
    let blocks = n / 16; // 8 bytes -> 16 codes per block
    for blk in 0..blocks {
        let b = vld1_u8(pb.add(blk * 8));
        let lo = vand_u8(b, nib);
        let hi = vshr_n_u8::<4>(b);
        let j = blk * 16;
        let (c0, c1) = widen8(vzip1_u8(lo, hi));
        let (c2, c3) = widen8(vzip2_u8(lo, hi));
        vst1q_f32(po.add(j), c0);
        vst1q_f32(po.add(j + 4), c1);
        vst1q_f32(po.add(j + 8), c2);
        vst1q_f32(po.add(j + 12), c3);
    }
    for p in blocks * 8..n / 2 {
        let byte = bytes[p];
        out[2 * p] = (byte & 0x0F) as f32;
        out[2 * p + 1] = (byte >> 4) as f32;
    }
}

/// Bit-identical to scalar: max is exact under any association, the
/// exp/sum pass stays sequential scalar, and the normalize multiply is
/// elementwise (vector and scalar round identically per element).
#[target_feature(enable = "neon")]
unsafe fn softmax_impl(xs: &mut [f32]) -> f32 {
    let n = xs.len();
    let p = xs.as_mut_ptr();
    let mut mv = vdupq_n_f32(f32::NEG_INFINITY);
    let chunks = n / 4;
    for i in 0..chunks {
        mv = vmaxq_f32(mv, vld1q_f32(p.add(i * 4)));
    }
    let mut max = vmaxvq_f32(mv);
    for x in xs[chunks * 4..].iter() {
        max = max.max(*x);
    }
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    let iv = vdupq_n_f32(inv);
    // Re-acquire: the iter_mut() pass above retired the earlier pointer.
    let p = xs.as_mut_ptr();
    for i in 0..chunks {
        vst1q_f32(p.add(i * 4), vmulq_f32(vld1q_f32(p.add(i * 4)), iv));
    }
    for x in xs[chunks * 4..].iter_mut() {
        *x *= inv;
    }
    max
}

#[target_feature(enable = "neon")]
unsafe fn rmsnorm_impl(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    let n = x.len();
    let px = x.as_ptr();
    let pw = w.as_ptr();
    let po = out.as_mut_ptr();
    let mut acc = vdupq_n_f32(0.0);
    let chunks = n / 4;
    for i in 0..chunks {
        let v = vld1q_f32(px.add(i * 4));
        acc = vfmaq_f32(acc, v, v);
    }
    let mut sumsq = vaddvq_f32(acc);
    for j in chunks * 4..n {
        sumsq += x[j] * x[j];
    }
    let inv = 1.0 / (sumsq / n as f32 + eps).sqrt();
    let iv = vdupq_n_f32(inv);
    for i in 0..chunks {
        let j = i * 4;
        let scaled = vmulq_f32(vld1q_f32(px.add(j)), iv);
        vst1q_f32(po.add(j), vmulq_f32(scaled, vld1q_f32(pw.add(j))));
    }
    for j in chunks * 4..n {
        out[j] = x[j] * inv * w[j];
    }
}
