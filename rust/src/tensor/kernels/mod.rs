//! Runtime-dispatched SIMD kernel backend (DESIGN.md §11).
//!
//! Every hot inner loop of the serving stack — the f32 dot/axpy pair
//! under attention, the fused per-width dequant-dots of the SpGEMV
//! estimator, the page-tile code widening, fp16 loads, softmax and
//! rmsnorm — funnels through one table of function pointers
//! ([`Kernels`]). The table is resolved **once** from
//! `TWILIGHT_KERNEL={auto,scalar,avx2,neon}` (or `--kernel`) on first
//! use and cached in an atomic, so steady-state dispatch is a relaxed
//! load plus an indirect call; hot loops fetch the table once per call
//! ([`active`]) and amortize even that.
//!
//! ## Exactness contract
//!
//! * The **scalar** backend is byte-for-byte the historical loop bodies
//!   (moved here verbatim from `tensor/`, `tensor/quant.rs`, and
//!   `kvcache/`): under `TWILIGHT_KERNEL=scalar` every golden trace,
//!   allocation pin, and bit-exactness test reproduces exactly what the
//!   pre-dispatch code produced.
//! * **unpack_* / f16 widening** entries are value-exact in every
//!   backend: integer→f32 widening and f16→f32 conversion are exact
//!   operations, so the SIMD versions return identical bits (NaN
//!   payloads excepted — hardware f16 converts may quiet a signaling
//!   NaN; the K cache never stores NaNs).
//! * **softmax** is bit-identical in every backend: the max reduction
//!   is exact under any association, and the exp/sum pass stays
//!   sequential.
//! * **Reductions** (`dot`, `dot_strict`, `dot_q_*`, `dot_f16`,
//!   `axpy`, `rmsnorm`'s sum of squares) are eps-bounded across
//!   backends: SIMD reassociates the accumulation (and fuses
//!   multiply-add), so results differ from scalar by O(√n·ε) relative
//!   error — the same class of reordering `tensor::dot`'s 4-lane split
//!   already performs. `rust/tests/simd_parity.rs` pins the bound for
//!   every width and remainder-tail length.
//! * **Within** one SIMD backend, `dot_strict(q, widened)` and
//!   `dot_f16(q, packed)` share one accumulation structure, so the
//!   tiled-vs-rowmajor and gemv-vs-gemv_tiled bit-equality tests hold
//!   under *any* backend, not just scalar (the fp16 group path and tile
//!   path both route through `dot` for the same reason).
//!
//! ## Adding a backend
//!
//! Implement the table entries in a new `cfg(target_arch)`-gated
//! module, add a [`Backend`] variant + feature detection in
//! [`detect`], a [`Select`] name, and an id constant; the parity
//! battery and `fig14_kernels` pick it up from [`detect`]
//! automatically. Keep `unsafe` confined to `#[target_feature]` inner
//! functions whose safe wrappers document why the feature is present
//! (they are only reachable through a table installed after detection).

pub mod scalar;

#[cfg(target_arch = "x86_64")]
pub mod avx2;

#[cfg(target_arch = "aarch64")]
pub mod neon;

use std::sync::atomic::{AtomicU8, Ordering};

/// A compute backend the dispatch table can resolve to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// The bit-exact reference (the historical loop bodies).
    Scalar,
    /// x86_64 AVX2 + FMA + F16C (Haswell and later).
    Avx2,
    /// aarch64 NEON (baseline on AArch64).
    Neon,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2 => "avx2",
            Backend::Neon => "neon",
        }
    }

    /// Stable numeric id (exposed as the `twilight_kernel_backend_id`
    /// gauge: 0 = scalar, 1 = avx2, 2 = neon).
    pub fn id(self) -> u8 {
        match self {
            Backend::Scalar => 0,
            Backend::Avx2 => 1,
            Backend::Neon => 2,
        }
    }
}

/// A backend *request*, as parsed from `TWILIGHT_KERNEL` / `--kernel`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Select {
    /// Best supported backend for this host (the default).
    Auto,
    Scalar,
    Avx2,
    Neon,
}

impl Select {
    pub fn parse(s: &str) -> Option<Select> {
        match s {
            "auto" => Some(Select::Auto),
            "scalar" => Some(Select::Scalar),
            "avx2" => Some(Select::Avx2),
            "neon" => Some(Select::Neon),
            _ => None,
        }
    }
}

/// The kernel dispatch table: one function pointer per hot primitive.
///
/// Slice-length contracts (callers guarantee; debug-asserted in the
/// scalar reference): `dot`/`dot_strict`/`axpy` take equal-length
/// slices; `dot_q_i8` takes `packed.len() >= q.len()` bytes,
/// `dot_q_i4` `>= ceil(q.len()/2)`, `dot_q_i2` `>= ceil(q.len()/4)`,
/// `dot_f16` exactly `2 * q.len()`; `unpack_i8` widens `out.len()`
/// bytes, `unpack_i4` `out.len()/2` (out even), `unpack_i2`
/// `out.len()/4` (out multiple of 4), `unpack_f16` `2 * out.len()`
/// little-endian half words.
pub struct Kernels {
    pub backend: Backend,
    /// f32 dot with the throughput-oriented (reassociating) reduction.
    /// Scalar reference: the historical 4-lane split in `tensor::dot`.
    pub dot: fn(&[f32], &[f32]) -> f32,
    /// f32 dot whose accumulation structure matches `dot_f16` exactly
    /// (scalar: strictly sequential). Used where a widened-f16 row must
    /// reproduce the packed-f16 path bit-for-bit.
    pub dot_strict: fn(&[f32], &[f32]) -> f32,
    /// `out[i] += s * x[i]`.
    pub axpy: fn(f32, &[f32], &mut [f32]),
    /// Fused dequant-dot over INT8 codes: `zero·Σq + scale·dot(q, codes)`.
    pub dot_q_i8: fn(&[f32], &[u8], f32, f32) -> f32,
    /// Fused dequant-dot over INT4 nibble pairs (odd tails handled).
    pub dot_q_i4: fn(&[f32], &[u8], f32, f32) -> f32,
    /// Fused dequant-dot over INT2 crumbs.
    pub dot_q_i2: fn(&[f32], &[u8], f32, f32) -> f32,
    /// Dot against packed little-endian f16 words (no scale/zero; the
    /// halves ARE the values). Accumulation structure == `dot_strict`.
    pub dot_f16: fn(&[f32], &[u8]) -> f32,
    /// Widen INT8 codes to f32 (value-exact in every backend).
    pub unpack_i8: fn(&[u8], &mut [f32]),
    /// Widen INT4 nibble pairs to f32, element order (value-exact).
    pub unpack_i4: fn(&[u8], &mut [f32]),
    /// Widen INT2 crumbs to f32, element order (value-exact).
    pub unpack_i2: fn(&[u8], &mut [f32]),
    /// Widen packed little-endian f16 words to f32 (value-exact).
    pub unpack_f16: fn(&[u8], &mut [f32]),
    /// Batch f16→f32 over `u16` words (value-exact).
    pub f16_slice: fn(&[u16], &mut [f32]),
    /// In-place stable softmax; returns the max logit. Bit-identical in
    /// every backend (exact max + sequential exp/sum).
    pub softmax: fn(&mut [f32]) -> f32,
    /// RMSNorm `x·w/rms(x)`; the sum of squares is the only reduction.
    pub rmsnorm: fn(&[f32], &[f32], f32, &mut [f32]),
}

const ID_UNINIT: u8 = u8::MAX;
const ID_SCALAR: u8 = 0;
#[cfg(target_arch = "x86_64")]
const ID_AVX2: u8 = 1;
#[cfg(target_arch = "aarch64")]
const ID_NEON: u8 = 2;

/// The installed backend id; `ID_UNINIT` until first use.
static ACTIVE: AtomicU8 = AtomicU8::new(ID_UNINIT);

/// The active kernel table. First use resolves `TWILIGHT_KERNEL`
/// (default `auto`); afterwards this is a relaxed atomic load. An
/// unknown or host-unsupported env value warns and falls back to the
/// best supported backend (never panics — the CLI's `--kernel` path
/// surfaces a hard error instead via [`install`]).
#[inline]
pub fn active() -> &'static Kernels {
    match ACTIVE.load(Ordering::Relaxed) {
        ID_SCALAR => &scalar::TABLE,
        #[cfg(target_arch = "x86_64")]
        ID_AVX2 => &avx2::TABLE,
        #[cfg(target_arch = "aarch64")]
        ID_NEON => &neon::TABLE,
        _ => init_from_env(),
    }
}

/// Name of the active backend (for reports / logs / live stats).
pub fn active_name() -> &'static str {
    active().backend.name()
}

/// Best backend this host supports (feature detection; never fails —
/// scalar is always available).
pub fn detect() -> Backend {
    #[cfg(target_arch = "x86_64")]
    {
        // The AVX2 table also uses FMA (dots) and F16C (f16 loads);
        // all three ship together on every AVX2 CPU since Haswell, but
        // detect each anyway — a missing one falls back to scalar.
        if is_x86_feature_detected!("avx2")
            && is_x86_feature_detected!("fma")
            && is_x86_feature_detected!("f16c")
        {
            return Backend::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Backend::Neon;
        }
    }
    Backend::Scalar
}

/// The table for a specific backend, if this build/host supports it.
/// Does not touch the global selection — the parity tests and
/// `fig14_kernels` compare backends side by side through this.
pub fn table(b: Backend) -> Option<&'static Kernels> {
    match b {
        Backend::Scalar => Some(&scalar::TABLE),
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => {
            if detect() == Backend::Avx2 {
                Some(&avx2::TABLE)
            } else {
                None
            }
        }
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => {
            if detect() == Backend::Neon {
                Some(&neon::TABLE)
            } else {
                None
            }
        }
        #[allow(unreachable_patterns)]
        _ => None,
    }
}

/// Install a backend globally (overridable any time — tests and the CLI
/// switch backends after process start, which is why the slot is an
/// atomic and not a `OnceLock`). `Auto` resolves via [`detect`] and
/// cannot fail; a named backend errors if the build target or the CPU
/// does not support it, leaving the previous selection untouched.
pub fn install(sel: Select) -> Result<&'static Kernels, String> {
    let backend = match sel {
        Select::Auto => detect(),
        Select::Scalar => Backend::Scalar,
        Select::Avx2 => Backend::Avx2,
        Select::Neon => Backend::Neon,
    };
    let t = table(backend).ok_or_else(|| {
        format!(
            "kernel backend '{}' is not supported on this host (arch {}; detected best: '{}')",
            backend.name(),
            std::env::consts::ARCH,
            detect().name()
        )
    })?;
    ACTIVE.store(id_of(backend), Ordering::Relaxed);
    publish_metric(backend);
    Ok(t)
}

/// Force the bit-exact scalar reference (golden-trace and allocation
/// tests pin behavior with this; infallible by construction).
pub fn force_scalar() {
    install(Select::Scalar).expect("scalar backend is always available");
}

fn id_of(b: Backend) -> u8 {
    match b {
        Backend::Scalar => ID_SCALAR,
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => ID_AVX2,
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => ID_NEON,
        // Unreachable: `install` only stores ids for tables this build
        // actually carries (`table` returned Some above).
        #[allow(unreachable_patterns)]
        _ => ID_SCALAR,
    }
}

/// Record the selection in the obs metrics registry so a Prometheus
/// scrape shows which backend served the run.
fn publish_metric(b: Backend) {
    crate::obs::metrics::gauge(
        "twilight_kernel_backend_id",
        "Active SIMD kernel backend (0=scalar, 1=avx2, 2=neon)",
    )
    .set(b.id() as f64);
}

/// Cold path of [`active`]: resolve `TWILIGHT_KERNEL` and install. Two
/// racing threads resolve the same env value and store the same id, so
/// the race is benign.
#[cold]
fn init_from_env() -> &'static Kernels {
    let raw = std::env::var("TWILIGHT_KERNEL").unwrap_or_default();
    let sel = if raw.is_empty() {
        Select::Auto
    } else {
        match Select::parse(&raw) {
            Some(s) => s,
            None => {
                eprintln!(
                    "twilight: unknown TWILIGHT_KERNEL='{raw}' (use auto, scalar, avx2, or neon); \
                     using auto"
                );
                Select::Auto
            }
        }
    };
    match install(sel) {
        Ok(t) => t,
        Err(e) => {
            // Never panic from a library path: an explicitly requested
            // but unsupported backend degrades to the detected best.
            eprintln!("twilight: {e}; falling back to '{}'", detect().name());
            install(Select::Auto).expect("auto install cannot fail")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: in-crate unit tests share one process with the whole lib
    // test binary and therefore must NOT mutate the global selection
    // (`install`/`force_scalar`); they compare per-backend tables via
    // `table()` instead. The integration battery that does switch the
    // global lives in `rust/tests/simd_parity.rs` (own process).

    #[test]
    fn select_parses_all_names() {
        assert_eq!(Select::parse("auto"), Some(Select::Auto));
        assert_eq!(Select::parse("scalar"), Some(Select::Scalar));
        assert_eq!(Select::parse("avx2"), Some(Select::Avx2));
        assert_eq!(Select::parse("neon"), Some(Select::Neon));
        assert_eq!(Select::parse("avx512"), None);
        assert_eq!(Select::parse(""), None);
    }

    #[test]
    fn scalar_table_always_available() {
        let t = table(Backend::Scalar).expect("scalar table");
        assert_eq!(t.backend, Backend::Scalar);
        assert_eq!((t.dot)(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
    }

    #[test]
    fn detect_is_supported() {
        // Whatever detection picks must actually resolve to a table.
        let b = detect();
        assert!(table(b).is_some(), "detected backend {b:?} has no table");
    }

    #[test]
    fn backend_ids_are_stable() {
        assert_eq!(Backend::Scalar.id(), 0);
        assert_eq!(Backend::Avx2.id(), 1);
        assert_eq!(Backend::Neon.id(), 2);
        assert_eq!(Backend::Scalar.name(), "scalar");
    }

    #[test]
    fn active_resolves_without_panic() {
        // Whatever TWILIGHT_KERNEL says (CI legs set scalar/auto), the
        // first touch must resolve to a usable table.
        let k = active();
        assert_eq!((k.dot)(&[2.0], &[8.0]), 16.0);
        assert_eq!(active_name(), k.backend.name());
    }
}
