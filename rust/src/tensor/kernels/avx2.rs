//! AVX2 + FMA + F16C backend (x86_64, Haswell and later).
//!
//! Reductions run 8 lanes wide with fused multiply-add and are
//! eps-bounded against scalar (reassociation + FMA). The pairs that
//! must agree *bitwise* with each other share one accumulation
//! structure: `dot_strict` and `dot_f16` both use a single 8-wide
//! accumulator, the same horizontal sum, and the same sequential scalar
//! tail — so a dot against widened-f16 codes reproduces the packed-f16
//! fused dot exactly, keeping the tiled-vs-rowmajor bit-equality tests
//! green under this backend. Widening entries (`unpack_*`, `f16_slice`)
//! are value-exact: integer→f32 and f16→f32 conversions round nothing.
//!
//! `unsafe` discipline: every intrinsic body is a private
//! `#[target_feature(enable = "avx2,fma,f16c")] unsafe fn *_impl`; the
//! safe wrappers in the dispatch table are the only entry points, and
//! they are reachable only through a table that `kernels::detect()`
//! refused to hand out unless the host reports all three features.

use super::{scalar, Backend, Kernels};
use crate::tensor::fp16::f16_to_f32;
use core::arch::x86_64::*;

pub static TABLE: Kernels = Kernels {
    backend: Backend::Avx2,
    dot,
    dot_strict,
    axpy,
    dot_q_i8,
    dot_q_i4,
    dot_q_i2,
    dot_f16,
    unpack_i8,
    unpack_i4,
    // INT2 crumb interleave doesn't vectorize cleanly; the value-exact
    // scalar widening stays (the INT2 ablation is not a perf target).
    unpack_i2: scalar::unpack_i2,
    unpack_f16,
    f16_slice,
    softmax,
    rmsnorm,
};

// SAFETY (applies to every wrapper below): the `*_impl` functions
// require avx2+fma+f16c. This table is only reachable via
// `kernels::table(Backend::Avx2)`, which returns `None` unless
// `is_x86_feature_detected!` confirmed all three features on this CPU.

fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    unsafe { dot_impl(a, b) }
}

fn dot_strict(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    unsafe { dot_strict_impl(a, b) }
}

fn axpy(s: f32, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    unsafe { axpy_impl(s, x, out) }
}

fn dot_q_i8(q: &[f32], packed: &[u8], zero: f32, scale: f32) -> f32 {
    debug_assert!(packed.len() >= q.len());
    unsafe { dot_q_i8_impl(q, packed, zero, scale) }
}

fn dot_q_i4(q: &[f32], packed: &[u8], zero: f32, scale: f32) -> f32 {
    debug_assert!(packed.len() >= q.len().div_ceil(2));
    unsafe { dot_q_i4_impl(q, packed, zero, scale) }
}

fn dot_q_i2(q: &[f32], packed: &[u8], zero: f32, scale: f32) -> f32 {
    debug_assert!(packed.len() >= q.len().div_ceil(4));
    unsafe { dot_q_i2_impl(q, packed, zero, scale) }
}

fn dot_f16(q: &[f32], packed: &[u8]) -> f32 {
    debug_assert_eq!(packed.len(), 2 * q.len());
    unsafe { dot_f16_impl(q, packed) }
}

fn unpack_i8(bytes: &[u8], out: &mut [f32]) {
    debug_assert_eq!(bytes.len(), out.len());
    unsafe { unpack_i8_impl(bytes, out) }
}

fn unpack_i4(bytes: &[u8], out: &mut [f32]) {
    debug_assert_eq!(bytes.len() * 2, out.len());
    unsafe { unpack_i4_impl(bytes, out) }
}

fn unpack_f16(bytes: &[u8], out: &mut [f32]) {
    debug_assert_eq!(bytes.len(), 2 * out.len());
    unsafe { unpack_f16_impl(bytes, out) }
}

fn f16_slice(hs: &[u16], out: &mut [f32]) {
    debug_assert_eq!(hs.len(), out.len());
    unsafe { f16_slice_impl(hs, out) }
}

fn softmax(xs: &mut [f32]) -> f32 {
    if xs.is_empty() {
        return f32::NEG_INFINITY;
    }
    unsafe { softmax_impl(xs) }
}

fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), w.len());
    unsafe { rmsnorm_impl(x, w, eps, out) }
}

/// Horizontal sum of one 8-lane register. Shared by `dot_strict_impl`
/// and `dot_f16_impl` so their reductions stay bit-identical.
#[target_feature(enable = "avx2,fma,f16c")]
unsafe fn hsum8(v: __m256) -> f32 {
    let s = _mm_add_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps(v, 1));
    let s = _mm_add_ps(s, _mm_movehdup_ps(s));
    _mm_cvtss_f32(_mm_add_ss(s, _mm_movehl_ps(s, s)))
}

/// Throughput dot: 4 independent 8-lane FMA accumulators (32 elements
/// per iteration), then an 8-wide cleanup loop and a scalar tail.
#[target_feature(enable = "avx2,fma,f16c")]
unsafe fn dot_impl(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut acc2 = _mm256_setzero_ps();
    let mut acc3 = _mm256_setzero_ps();
    let blocks = n / 32;
    for i in 0..blocks {
        let j = i * 32;
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(j)), _mm256_loadu_ps(pb.add(j)), acc0);
        acc1 =
            _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(j + 8)), _mm256_loadu_ps(pb.add(j + 8)), acc1);
        acc2 = _mm256_fmadd_ps(
            _mm256_loadu_ps(pa.add(j + 16)),
            _mm256_loadu_ps(pb.add(j + 16)),
            acc2,
        );
        acc3 = _mm256_fmadd_ps(
            _mm256_loadu_ps(pa.add(j + 24)),
            _mm256_loadu_ps(pb.add(j + 24)),
            acc3,
        );
    }
    let mut j = blocks * 32;
    while j + 8 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(j)), _mm256_loadu_ps(pb.add(j)), acc0);
        j += 8;
    }
    let mut s = hsum8(_mm256_add_ps(_mm256_add_ps(acc0, acc1), _mm256_add_ps(acc2, acc3)));
    while j < n {
        s += a[j] * b[j];
        j += 1;
    }
    s
}

/// Single-accumulator dot, structurally paired with `dot_f16_impl`.
#[target_feature(enable = "avx2,fma,f16c")]
unsafe fn dot_strict_impl(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let mut acc = _mm256_setzero_ps();
    let chunks = n / 8;
    for i in 0..chunks {
        let j = i * 8;
        acc = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(j)), _mm256_loadu_ps(pb.add(j)), acc);
    }
    let mut s = hsum8(acc);
    for j in chunks * 8..n {
        s += a[j] * b[j];
    }
    s
}

#[target_feature(enable = "avx2,fma,f16c")]
unsafe fn axpy_impl(s: f32, x: &[f32], out: &mut [f32]) {
    let n = x.len();
    let sv = _mm256_set1_ps(s);
    let px = x.as_ptr();
    let po = out.as_mut_ptr();
    let chunks = n / 8;
    for i in 0..chunks {
        let j = i * 8;
        let o = _mm256_fmadd_ps(sv, _mm256_loadu_ps(px.add(j)), _mm256_loadu_ps(po.add(j)));
        _mm256_storeu_ps(po.add(j), o);
    }
    for j in chunks * 8..n {
        out[j] += s * x[j];
    }
}

#[target_feature(enable = "avx2,fma,f16c")]
unsafe fn dot_q_i8_impl(q: &[f32], packed: &[u8], zero: f32, scale: f32) -> f32 {
    let n = q.len();
    let pq = q.as_ptr();
    let pc = packed.as_ptr();
    let mut code_acc = _mm256_setzero_ps();
    let mut qsum_acc = _mm256_setzero_ps();
    let chunks = n / 8;
    for i in 0..chunks {
        let j = i * 8;
        // 8 unsigned codes -> i32 -> f32 (exact: codes are <= 255).
        let bytes = _mm_loadl_epi64(pc.add(j) as *const __m128i);
        let codes = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(bytes));
        let qv = _mm256_loadu_ps(pq.add(j));
        code_acc = _mm256_fmadd_ps(qv, codes, code_acc);
        qsum_acc = _mm256_add_ps(qsum_acc, qv);
    }
    let mut code_dot = hsum8(code_acc);
    let mut qsum = hsum8(qsum_acc);
    for j in chunks * 8..n {
        code_dot += q[j] * packed[j] as f32;
        qsum += q[j];
    }
    zero * qsum + scale * code_dot
}

#[target_feature(enable = "avx2,fma,f16c")]
unsafe fn dot_q_i4_impl(q: &[f32], packed: &[u8], zero: f32, scale: f32) -> f32 {
    let n = q.len();
    let pq = q.as_ptr();
    let pc = packed.as_ptr();
    let nib = _mm_set1_epi8(0x0F);
    let mut code_acc = _mm256_setzero_ps();
    let mut qsum_acc = _mm256_setzero_ps();
    // 16 packed bytes = 32 codes per block, restored to element order
    // (low nibble first) by interleaving the masked halves.
    let blocks = n / 32;
    for blk in 0..blocks {
        let bytes = _mm_loadu_si128(pc.add(blk * 16) as *const __m128i);
        let lo = _mm_and_si128(bytes, nib);
        let hi = _mm_and_si128(_mm_srli_epi16(bytes, 4), nib);
        let il0 = _mm_unpacklo_epi8(lo, hi); // codes 0..16
        let il1 = _mm_unpackhi_epi8(lo, hi); // codes 16..32
        let groups = [
            _mm256_cvtepu8_epi32(il0),
            _mm256_cvtepu8_epi32(_mm_srli_si128(il0, 8)),
            _mm256_cvtepu8_epi32(il1),
            _mm256_cvtepu8_epi32(_mm_srli_si128(il1, 8)),
        ];
        for (k, g) in groups.iter().enumerate() {
            let codes = _mm256_cvtepi32_ps(*g);
            let qv = _mm256_loadu_ps(pq.add(blk * 32 + k * 8));
            code_acc = _mm256_fmadd_ps(qv, codes, code_acc);
            qsum_acc = _mm256_add_ps(qsum_acc, qv);
        }
    }
    let mut code_dot = hsum8(code_acc);
    let mut qsum = hsum8(qsum_acc);
    for i in blocks * 32..n {
        let byte = packed[i / 2];
        let code = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
        code_dot += q[i] * code as f32;
        qsum += q[i];
    }
    zero * qsum + scale * code_dot
}

#[target_feature(enable = "avx2,fma,f16c")]
unsafe fn dot_q_i2_impl(q: &[f32], packed: &[u8], zero: f32, scale: f32) -> f32 {
    let n = q.len();
    let pq = q.as_ptr();
    let mut code_acc = _mm256_setzero_ps();
    let mut qsum_acc = _mm256_setzero_ps();
    // Crumb interleave is branchy; widen 16 codes (4 bytes) to a stack
    // tile scalar-side, keep the multiply-accumulate vectorized.
    let mut tile = [0.0f32; 16];
    let blocks = n / 16;
    for blk in 0..blocks {
        for (p, &byte) in packed[blk * 4..blk * 4 + 4].iter().enumerate() {
            tile[4 * p] = (byte & 0x03) as f32;
            tile[4 * p + 1] = ((byte >> 2) & 0x03) as f32;
            tile[4 * p + 2] = ((byte >> 4) & 0x03) as f32;
            tile[4 * p + 3] = (byte >> 6) as f32;
        }
        for k in 0..2 {
            let codes = _mm256_loadu_ps(tile.as_ptr().add(k * 8));
            let qv = _mm256_loadu_ps(pq.add(blk * 16 + k * 8));
            code_acc = _mm256_fmadd_ps(qv, codes, code_acc);
            qsum_acc = _mm256_add_ps(qsum_acc, qv);
        }
    }
    let mut code_dot = hsum8(code_acc);
    let mut qsum = hsum8(qsum_acc);
    for i in blocks * 16..n {
        let code = (packed[i / 4] >> ((i % 4) * 2)) & 0x03;
        code_dot += q[i] * code as f32;
        qsum += q[i];
    }
    zero * qsum + scale * code_dot
}

/// Fused fp16 dot: F16C converts (exactly) 8 halves per load, FMA into
/// a single accumulator — the structure `dot_strict_impl` mirrors.
#[target_feature(enable = "avx2,fma,f16c")]
unsafe fn dot_f16_impl(q: &[f32], packed: &[u8]) -> f32 {
    let n = q.len();
    let pq = q.as_ptr();
    let pc = packed.as_ptr();
    let mut acc = _mm256_setzero_ps();
    let chunks = n / 8;
    for i in 0..chunks {
        let h = _mm_loadu_si128(pc.add(i * 16) as *const __m128i);
        let v = _mm256_cvtph_ps(h);
        acc = _mm256_fmadd_ps(_mm256_loadu_ps(pq.add(i * 8)), v, acc);
    }
    let mut s = hsum8(acc);
    for i in chunks * 8..n {
        let h = u16::from_le_bytes([packed[2 * i], packed[2 * i + 1]]);
        s += q[i] * f16_to_f32(h);
    }
    s
}

#[target_feature(enable = "avx2,fma,f16c")]
unsafe fn unpack_i8_impl(bytes: &[u8], out: &mut [f32]) {
    let n = out.len();
    let pb = bytes.as_ptr();
    let po = out.as_mut_ptr();
    let chunks = n / 8;
    for i in 0..chunks {
        let j = i * 8;
        let b = _mm_loadl_epi64(pb.add(j) as *const __m128i);
        _mm256_storeu_ps(po.add(j), _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(b)));
    }
    for j in chunks * 8..n {
        out[j] = bytes[j] as f32;
    }
}

#[target_feature(enable = "avx2,fma,f16c")]
unsafe fn unpack_i4_impl(bytes: &[u8], out: &mut [f32]) {
    let n = out.len(); // even; bytes.len() == n / 2
    let pb = bytes.as_ptr();
    let po = out.as_mut_ptr();
    let nib = _mm_set1_epi8(0x0F);
    let blocks = n / 16; // 8 bytes -> 16 codes per block
    for blk in 0..blocks {
        let b = _mm_loadl_epi64(pb.add(blk * 8) as *const __m128i);
        let lo = _mm_and_si128(b, nib);
        let hi = _mm_and_si128(_mm_srli_epi16(b, 4), nib);
        let il = _mm_unpacklo_epi8(lo, hi); // 16 codes in element order
        let j = blk * 16;
        _mm256_storeu_ps(po.add(j), _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(il)));
        _mm256_storeu_ps(
            po.add(j + 8),
            _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(_mm_srli_si128(il, 8))),
        );
    }
    for p in blocks * 8..n / 2 {
        let byte = bytes[p];
        out[2 * p] = (byte & 0x0F) as f32;
        out[2 * p + 1] = (byte >> 4) as f32;
    }
}

#[target_feature(enable = "avx2,fma,f16c")]
unsafe fn unpack_f16_impl(bytes: &[u8], out: &mut [f32]) {
    let n = out.len();
    let pb = bytes.as_ptr();
    let po = out.as_mut_ptr();
    let chunks = n / 8;
    for i in 0..chunks {
        let h = _mm_loadu_si128(pb.add(i * 16) as *const __m128i);
        _mm256_storeu_ps(po.add(i * 8), _mm256_cvtph_ps(h));
    }
    for i in chunks * 8..n {
        let h = u16::from_le_bytes([bytes[2 * i], bytes[2 * i + 1]]);
        out[i] = f16_to_f32(h);
    }
}

#[target_feature(enable = "avx2,fma,f16c")]
unsafe fn f16_slice_impl(hs: &[u16], out: &mut [f32]) {
    let n = out.len();
    let ph = hs.as_ptr();
    let po = out.as_mut_ptr();
    let chunks = n / 8;
    for i in 0..chunks {
        let h = _mm_loadu_si128(ph.add(i * 8) as *const __m128i);
        _mm256_storeu_ps(po.add(i * 8), _mm256_cvtph_ps(h));
    }
    for i in chunks * 8..n {
        out[i] = f16_to_f32(hs[i]);
    }
}

/// Bit-identical to scalar: max is exact under any association, the
/// exp/sum pass stays sequential scalar, and the normalize multiply is
/// elementwise (vector and scalar round identically per element).
#[target_feature(enable = "avx2,fma,f16c")]
unsafe fn softmax_impl(xs: &mut [f32]) -> f32 {
    let n = xs.len();
    let p = xs.as_mut_ptr();
    let mut mv = _mm256_set1_ps(f32::NEG_INFINITY);
    let chunks = n / 8;
    for i in 0..chunks {
        mv = _mm256_max_ps(mv, _mm256_loadu_ps(p.add(i * 8)));
    }
    let m = _mm_max_ps(_mm256_castps256_ps128(mv), _mm256_extractf128_ps(mv, 1));
    let m = _mm_max_ps(m, _mm_movehdup_ps(m));
    let mut max = _mm_cvtss_f32(_mm_max_ss(m, _mm_movehl_ps(m, m)));
    for x in xs[chunks * 8..].iter() {
        max = max.max(*x);
    }
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    let iv = _mm256_set1_ps(inv);
    // Re-acquire: the iter_mut() pass above retired the earlier pointer.
    let p = xs.as_mut_ptr();
    for i in 0..chunks {
        _mm256_storeu_ps(p.add(i * 8), _mm256_mul_ps(_mm256_loadu_ps(p.add(i * 8)), iv));
    }
    for x in xs[chunks * 8..].iter_mut() {
        *x *= inv;
    }
    max
}

#[target_feature(enable = "avx2,fma,f16c")]
unsafe fn rmsnorm_impl(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    let n = x.len();
    let px = x.as_ptr();
    let pw = w.as_ptr();
    let po = out.as_mut_ptr();
    let mut acc = _mm256_setzero_ps();
    let chunks = n / 8;
    for i in 0..chunks {
        let v = _mm256_loadu_ps(px.add(i * 8));
        acc = _mm256_fmadd_ps(v, v, acc);
    }
    let mut sumsq = hsum8(acc);
    for j in chunks * 8..n {
        sumsq += x[j] * x[j];
    }
    let inv = 1.0 / (sumsq / n as f32 + eps).sqrt();
    let iv = _mm256_set1_ps(inv);
    for i in 0..chunks {
        let j = i * 8;
        let scaled = _mm256_mul_ps(_mm256_loadu_ps(px.add(j)), iv);
        _mm256_storeu_ps(po.add(j), _mm256_mul_ps(scaled, _mm256_loadu_ps(pw.add(j))));
    }
    for j in chunks * 8..n {
        out[j] = x[j] * inv * w[j];
    }
}
