//! Scalar reference backend: the historical hot-loop bodies, moved here
//! **verbatim** from `tensor/mod.rs`, `tensor/quant.rs`, and the fp16
//! arm of `kvcache::quant_dot_row_qsum`. This table defines the
//! bit-exact behavior that the golden decode trace and the allocation
//! pin force with `TWILIGHT_KERNEL=scalar`; SIMD backends are measured
//! against it by the parity battery. Do not "optimize" these bodies —
//! any reassociation here moves the golden reference.

use super::{Backend, Kernels};
use crate::tensor::fp16::f16_to_f32;

pub static TABLE: Kernels = Kernels {
    backend: Backend::Scalar,
    dot,
    dot_strict,
    axpy,
    dot_q_i8,
    dot_q_i4,
    dot_q_i2,
    dot_f16,
    unpack_i8,
    unpack_i4,
    unpack_i2,
    unpack_f16,
    f16_slice,
    softmax,
    rmsnorm,
};

/// The historical `tensor::dot`: 4 independent accumulator lanes plus a
/// sequential tail — already a (fixed) reassociation, kept bit-for-bit.
pub(super) fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for j in chunks * 4..a.len() {
        s += a[j] * b[j];
    }
    s
}

/// Strictly sequential dot — the accumulation order of the historical
/// fp16 row-scoring loop, so `dot_strict(q, widened_f16)` reproduces
/// `dot_f16(q, packed)` bit-for-bit.
pub(super) fn dot_strict(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

pub(super) fn axpy(s: f32, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    for (o, xi) in out.iter_mut().zip(x) {
        *o += s * xi;
    }
}

/// Historical `dot_quantized` Int8 arm (fused: qsum inside, zipped).
pub(super) fn dot_q_i8(q: &[f32], packed: &[u8], zero: f32, scale: f32) -> f32 {
    debug_assert!(packed.len() >= q.len());
    let mut code_dot = 0.0f32;
    let mut qsum = 0.0f32;
    for (&qi, &c) in q.iter().zip(packed.iter()) {
        code_dot += qi * c as f32;
        qsum += qi;
    }
    zero * qsum + scale * code_dot
}

/// Historical `dot_quantized` Int4 arm. NB: qsum accumulates *pairwise*
/// (`q0 + q1` per byte) — bitwise different from a sequential sum; the
/// fused signature exists precisely to preserve this order.
pub(super) fn dot_q_i4(q: &[f32], packed: &[u8], zero: f32, scale: f32) -> f32 {
    let n = q.len();
    debug_assert!(packed.len() >= n.div_ceil(2));
    let mut code_dot = 0.0f32;
    let mut qsum = 0.0f32;
    let pairs = n / 2;
    for p in 0..pairs {
        let byte = packed[p];
        let q0 = q[2 * p];
        let q1 = q[2 * p + 1];
        code_dot += q0 * (byte & 0x0F) as f32 + q1 * (byte >> 4) as f32;
        qsum += q0 + q1;
    }
    if n % 2 == 1 {
        let i = n - 1;
        let code = packed[i / 2] & 0x0F;
        code_dot += q[i] * code as f32;
        qsum += q[i];
    }
    zero * qsum + scale * code_dot
}

/// Historical `dot_quantized` Int2 arm (sequential crumb walk).
pub(super) fn dot_q_i2(q: &[f32], packed: &[u8], zero: f32, scale: f32) -> f32 {
    debug_assert!(packed.len() >= q.len().div_ceil(4));
    let mut code_dot = 0.0f32;
    let mut qsum = 0.0f32;
    for (i, &qi) in q.iter().enumerate() {
        let code = (packed[i / 4] >> ((i % 4) * 2)) & 0x03;
        code_dot += qi * code as f32;
        qsum += qi;
    }
    zero * qsum + scale * code_dot
}

/// Historical fp16 fused dot (the `dot_quantized` Fp16 arm and the
/// kvcache fp16 row-scoring loop share this exact sequential order).
pub(super) fn dot_f16(q: &[f32], packed: &[u8]) -> f32 {
    debug_assert_eq!(packed.len(), 2 * q.len());
    let mut acc = 0.0f32;
    for (i, &qi) in q.iter().enumerate() {
        let h = u16::from_le_bytes([packed[2 * i], packed[2 * i + 1]]);
        acc += qi * f16_to_f32(h);
    }
    acc
}

/// Historical `unpack_codes_into` Int8 arm (over the pre-sliced window).
pub(super) fn unpack_i8(bytes: &[u8], out: &mut [f32]) {
    debug_assert_eq!(bytes.len(), out.len());
    for (o, &byte) in out.iter_mut().zip(bytes) {
        *o = byte as f32;
    }
}

/// Historical `unpack_codes_into` Int4 arm (lo nibble = even element).
pub(super) fn unpack_i4(bytes: &[u8], out: &mut [f32]) {
    debug_assert_eq!(bytes.len() * 2, out.len());
    for (p, &byte) in bytes.iter().enumerate() {
        out[2 * p] = (byte & 0x0F) as f32;
        out[2 * p + 1] = (byte >> 4) as f32;
    }
}

/// Historical `unpack_codes_into` Int2 arm.
pub(super) fn unpack_i2(bytes: &[u8], out: &mut [f32]) {
    debug_assert_eq!(bytes.len() * 4, out.len());
    for (p, &byte) in bytes.iter().enumerate() {
        out[4 * p] = (byte & 0x03) as f32;
        out[4 * p + 1] = ((byte >> 2) & 0x03) as f32;
        out[4 * p + 2] = ((byte >> 4) & 0x03) as f32;
        out[4 * p + 3] = (byte >> 6) as f32;
    }
}

/// Historical `unpack_codes_into` Fp16 arm over pre-sliced LE bytes.
pub(super) fn unpack_f16(bytes: &[u8], out: &mut [f32]) {
    debug_assert_eq!(bytes.len(), 2 * out.len());
    for (i, o) in out.iter_mut().enumerate() {
        let h = u16::from_le_bytes([bytes[2 * i], bytes[2 * i + 1]]);
        *o = f16_to_f32(h);
    }
}

/// Batch f16→f32 over `u16` words (`fp16::decode_into`'s loop body).
pub(super) fn f16_slice(hs: &[u16], out: &mut [f32]) {
    debug_assert_eq!(hs.len(), out.len());
    for (o, &h) in out.iter_mut().zip(hs) {
        *o = f16_to_f32(h);
    }
}

/// Historical `tensor::softmax_inplace`.
pub(super) fn softmax(xs: &mut [f32]) -> f32 {
    if xs.is_empty() {
        return f32::NEG_INFINITY;
    }
    let max = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in xs.iter_mut() {
        *x *= inv;
    }
    max
}

/// Historical `tensor::rmsnorm`.
pub(super) fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), w.len());
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for ((o, xi), wi) in out.iter_mut().zip(x).zip(w) {
        *o = xi * inv * wi;
    }
}
