//! Per-head asymmetric K-cache quantization (paper §4.2, Appendix B.1).
//!
//! The Twilight pruner estimates attention weights from a low-precision
//! mirror of the K cache. Following the paper (which follows QServe) we
//! use *per-head, dynamic, asymmetric* quantization: each (head, page)
//! group stores an fp16 `scale`/`zero` pair; INT4 elements are packed two
//! per byte after a `+offset` shift to unsigned (paper's `+128` trick,
//! here `+2^(bits-1)` at each width), interleaved in element order.
//!
//! INT2 and INT8 variants exist for the Fig. 6 / Fig. 12 ablations.

/// Quantization width for the mirror K cache.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantBits {
    Int2,
    Int4,
    Int8,
    /// No quantization: fp16 storage (baseline precision).
    Fp16,
}

impl QuantBits {
    pub fn bits(self) -> usize {
        match self {
            QuantBits::Int2 => 2,
            QuantBits::Int4 => 4,
            QuantBits::Int8 => 8,
            QuantBits::Fp16 => 16,
        }
    }

    /// Bytes needed to store `n` elements at this width.
    pub fn bytes_for(self, n: usize) -> usize {
        (n * self.bits()).div_ceil(8)
    }

    pub fn levels(self) -> usize {
        1usize << self.bits().min(16)
    }

    pub fn parse(s: &str) -> Option<QuantBits> {
        match s {
            "int2" | "2" => Some(QuantBits::Int2),
            "int4" | "4" => Some(QuantBits::Int4),
            "int8" | "8" => Some(QuantBits::Int8),
            "fp16" | "16" => Some(QuantBits::Fp16),
            _ => None,
        }
    }
}

/// A quantized block: packed codes plus the (scale, zero) pair.
/// `dequant(x) = (code - zero_point) * scale` with codes unsigned.
#[derive(Clone, Debug)]
pub struct QuantBlock {
    pub bits: QuantBits,
    pub n: usize,
    pub packed: Vec<u8>,
    pub scale: f32,
    pub zero: f32,
}

/// Quantize `xs` asymmetrically at `bits`; `Fp16` stores raw half bits.
pub fn quantize(xs: &[f32], bits: QuantBits) -> QuantBlock {
    if bits == QuantBits::Fp16 {
        let mut packed = Vec::with_capacity(xs.len() * 2);
        for &x in xs {
            packed.extend_from_slice(&super::fp16::f32_to_f16(x).to_le_bytes());
        }
        return QuantBlock { bits, n: xs.len(), packed, scale: 1.0, zero: 0.0 };
    }
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if !lo.is_finite() || !hi.is_finite() {
        lo = 0.0;
        hi = 0.0;
    }
    let levels = (bits.levels() - 1) as f32;
    let scale = if hi > lo { (hi - lo) / levels } else { 1.0 };
    let zero = lo; // dequant(code) = zero + code*scale
    let inv = 1.0 / scale;
    let nbits = bits.bits();
    let mut packed = vec![0u8; bits.bytes_for(xs.len())];
    for (i, &x) in xs.iter().enumerate() {
        let code = (((x - zero) * inv).round().clamp(0.0, levels)) as u32;
        let bitpos = i * nbits;
        let byte = bitpos / 8;
        let off = bitpos % 8;
        packed[byte] |= (code as u8) << off;
        // INT4/INT2 never straddle a byte; INT8 fills the byte exactly.
    }
    QuantBlock { bits, n: xs.len(), packed, scale, zero }
}

/// Dequantize into `out` (len == n).
pub fn dequantize_into(b: &QuantBlock, out: &mut [f32]) {
    assert_eq!(out.len(), b.n);
    match b.bits {
        QuantBits::Fp16 => {
            for (i, o) in out.iter_mut().enumerate() {
                let h = u16::from_le_bytes([b.packed[2 * i], b.packed[2 * i + 1]]);
                *o = super::fp16::f16_to_f32(h);
            }
        }
        QuantBits::Int8 => {
            for (i, o) in out.iter_mut().enumerate() {
                *o = b.zero + b.packed[i] as f32 * b.scale;
            }
        }
        QuantBits::Int4 => {
            // Two codes per byte; build per-block LUT-free unpack.
            for (i, o) in out.iter_mut().enumerate() {
                let byte = b.packed[i / 2];
                let code = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                *o = b.zero + code as f32 * b.scale;
            }
        }
        QuantBits::Int2 => {
            for (i, o) in out.iter_mut().enumerate() {
                let byte = b.packed[i / 4];
                let code = (byte >> ((i % 4) * 2)) & 0x03;
                *o = b.zero + code as f32 * b.scale;
            }
        }
    }
}

/// Fused dequant-and-dot: `sum_i q[i] * dequant(K)[i]` without
/// materializing the dequantized vector. This is the SpGEMV inner loop
/// (paper Appendix B.1) — the hot path of the Twilight pruner.
///
/// Identity used: `dot(q, zero + code*scale) = zero*sum(q) + scale*dot(q, code)`,
/// so the loop only multiplies integer codes, then applies scale/zero once.
/// The per-width entries are *fused* (qsum is accumulated inside, in
/// the historical order — Int4's is pairwise) so the scalar backend is
/// bit-for-bit the pre-dispatch loops; SIMD backends are eps-bounded.
#[inline]
pub fn dot_quantized(q: &[f32], b: &QuantBlock) -> f32 {
    debug_assert_eq!(q.len(), b.n);
    let kn = super::kernels::active();
    match b.bits {
        QuantBits::Fp16 => (kn.dot_f16)(q, &b.packed),
        QuantBits::Int8 => (kn.dot_q_i8)(q, &b.packed, b.zero, b.scale),
        QuantBits::Int4 => (kn.dot_q_i4)(q, &b.packed, b.zero, b.scale),
        QuantBits::Int2 => (kn.dot_q_i2)(q, &b.packed, b.zero, b.scale),
    }
}

/// Widen the packed codes of elements `[first, first + out.len())` into
/// `out` as f32 — *codes*, not dequantized values (Fp16 widens the stored
/// halves, which are the "codes" of that width). This is the page-tile
/// unpack of the tiled SpGEMV: a run of rows sharing one block unpacks
/// its window once, then every (row × query-head) contraction reads the
/// tile. The widening expressions are byte-for-byte the ones
/// `quant_dot_row_qsum` / `quant_dot_row_group` use for their per-row
/// stack buffers, so a dot over a tile row is bit-identical to the
/// row-major fused path.
/// The widenings are value-exact in every kernel backend (integer→f32
/// and f16→f32 round nothing), so tile dots stay bit-identical to the
/// row-major fused path under SIMD too.
pub fn unpack_codes_into(b: &QuantBlock, first: usize, out: &mut [f32]) {
    debug_assert!(first + out.len() <= b.n);
    let kn = super::kernels::active();
    match b.bits {
        QuantBits::Fp16 => {
            (kn.unpack_f16)(&b.packed[2 * first..2 * (first + out.len())], out)
        }
        QuantBits::Int8 => (kn.unpack_i8)(&b.packed[first..first + out.len()], out),
        QuantBits::Int4 => {
            // Rows are d-aligned with d even, so windows start and end on
            // byte boundaries (same precondition as the row-major path).
            debug_assert!(first % 2 == 0 && out.len() % 2 == 0);
            (kn.unpack_i4)(&b.packed[first / 2..first / 2 + out.len() / 2], out)
        }
        QuantBits::Int2 => {
            debug_assert!(first % 4 == 0 && out.len() % 4 == 0);
            (kn.unpack_i2)(&b.packed[first / 4..first / 4 + out.len() / 4], out)
        }
    }
}

/// Worst-case absolute dequantization error for a block: half a step.
pub fn max_error(b: &QuantBlock) -> f32 {
    match b.bits {
        QuantBits::Fp16 => 1e-3, // relative ~2^-11; coarse bound for tests
        _ => b.scale * 0.5,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip_err(bits: QuantBits, xs: &[f32]) -> f32 {
        let b = quantize(xs, bits);
        let mut out = vec![0.0; xs.len()];
        dequantize_into(&b, &mut out);
        xs.iter().zip(&out).map(|(a, c)| (a - c).abs()).fold(0.0f32, f32::max)
    }

    #[test]
    fn int8_roundtrip_tight() {
        let mut r = Rng::new(1);
        let xs: Vec<f32> = (0..128).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let b = quantize(&xs, QuantBits::Int8);
        assert!(roundtrip_err(QuantBits::Int8, &xs) <= max_error(&b) + 1e-6);
    }

    #[test]
    fn int4_roundtrip_within_step() {
        let mut r = Rng::new(2);
        let xs: Vec<f32> = (0..128).map(|_| r.normal_f32(0.0, 2.0)).collect();
        let b = quantize(&xs, QuantBits::Int4);
        assert!(roundtrip_err(QuantBits::Int4, &xs) <= max_error(&b) + 1e-6);
    }

    #[test]
    fn int2_is_coarse_but_bounded() {
        let mut r = Rng::new(3);
        let xs: Vec<f32> = (0..64).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let b = quantize(&xs, QuantBits::Int2);
        assert!(roundtrip_err(QuantBits::Int2, &xs) <= max_error(&b) + 1e-6);
        // And strictly worse than int4 on the same data (sanity of ablation).
        assert!(roundtrip_err(QuantBits::Int2, &xs) > roundtrip_err(QuantBits::Int4, &xs));
    }

    #[test]
    fn fp16_roundtrip() {
        let xs = vec![0.5, -1.25, 3.75, 0.0];
        assert!(roundtrip_err(QuantBits::Fp16, &xs) < 1e-3);
    }

    #[test]
    fn extremes_are_exact() {
        // Asymmetric quant maps min -> code 0 and max -> top code exactly.
        let xs = vec![-3.0, 0.1, 0.2, 5.0];
        for bits in [QuantBits::Int2, QuantBits::Int4, QuantBits::Int8] {
            let b = quantize(&xs, bits);
            let mut out = vec![0.0; 4];
            dequantize_into(&b, &mut out);
            assert!((out[0] + 3.0).abs() < 1e-5, "{bits:?} {out:?}");
            assert!((out[3] - 5.0).abs() < 1e-4, "{bits:?} {out:?}");
        }
    }

    #[test]
    fn dot_quantized_matches_dequant_dot() {
        let mut r = Rng::new(7);
        for bits in [QuantBits::Int2, QuantBits::Int4, QuantBits::Int8, QuantBits::Fp16] {
            for n in [1usize, 2, 7, 64, 128, 129] {
                let xs: Vec<f32> = (0..n).map(|_| r.normal_f32(0.0, 1.0)).collect();
                let q: Vec<f32> = (0..n).map(|_| r.normal_f32(0.0, 1.0)).collect();
                let b = quantize(&xs, bits);
                let mut deq = vec![0.0; n];
                dequantize_into(&b, &mut deq);
                let want: f32 = q.iter().zip(&deq).map(|(a, c)| a * c).sum();
                let got = dot_quantized(&q, &b);
                assert!(
                    (want - got).abs() < 1e-3 * n as f32,
                    "bits={bits:?} n={n} want={want} got={got}"
                );
            }
        }
    }

    #[test]
    fn constant_input() {
        let xs = vec![2.5; 32];
        for bits in [QuantBits::Int2, QuantBits::Int4, QuantBits::Int8] {
            let b = quantize(&xs, bits);
            let mut out = vec![0.0; 32];
            dequantize_into(&b, &mut out);
            for o in out {
                assert!((o - 2.5).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn unpack_codes_windows_match_dequant() {
        // Any aligned window of unpacked codes must reproduce
        // dequantize_into exactly via zero + code*scale (Fp16: the codes
        // ARE the values).
        let mut r = Rng::new(11);
        let n = 64;
        let xs: Vec<f32> = (0..n).map(|_| r.normal_f32(0.0, 1.5)).collect();
        for bits in [QuantBits::Int2, QuantBits::Int4, QuantBits::Int8, QuantBits::Fp16] {
            let b = quantize(&xs, bits);
            let mut full = vec![0.0; n];
            dequantize_into(&b, &mut full);
            for (first, len) in [(0usize, n), (16, 32), (8, 8), (60, 4)] {
                let mut codes = vec![0.0; len];
                unpack_codes_into(&b, first, &mut codes);
                for (i, &c) in codes.iter().enumerate() {
                    let want = full[first + i];
                    let got = if bits == QuantBits::Fp16 { c } else { b.zero + c * b.scale };
                    assert_eq!(got, want, "bits={bits:?} first={first} i={i}");
                }
            }
        }
    }

    #[test]
    fn bytes_for_widths() {
        assert_eq!(QuantBits::Int4.bytes_for(128), 64);
        assert_eq!(QuantBits::Int2.bytes_for(128), 32);
        assert_eq!(QuantBits::Int8.bytes_for(128), 128);
        assert_eq!(QuantBits::Fp16.bytes_for(128), 256);
        assert_eq!(QuantBits::Int4.bytes_for(3), 2);
    }
}
