//! Scoped worker pool for head-varlen attention load balancing.
//!
//! FlashInfer balances head-wise dynamic budgets by flattening the
//! (sequence, head) dimension into a single work list; we do the same
//! with a chunked atomic work queue drained by scoped worker threads
//! (spawned per call — a persistent pool amortizing the spawn/join
//! across layers is a tracked follow-up). The engine's batched decode
//! step uses this to drain the LPT-partitioned per-worker buckets of
//! its phase-(b) attention work list (one index per bucket,
//! `chunk = 1`); with `TWILIGHT_THREADS=1` the queue degenerates to a
//! plain loop on the caller thread, which is the bit-exact sequential
//! reference the parity tests compare against.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Execute `work(i)` for every `i in 0..n` across `threads` workers,
/// dynamically load-balanced in chunks of `chunk` items.
pub fn parallel_for<F: Fn(usize) + Sync>(threads: usize, n: usize, chunk: usize, work: F) {
    let threads = threads.max(1);
    let chunk = chunk.max(1);
    if threads == 1 || n <= chunk {
        for i in 0..n {
            work(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let start = next.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    work(i);
                }
            });
        }
    });
}

/// Number of workers to use by default: respects `TWILIGHT_THREADS`,
/// falling back to available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("TWILIGHT_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_all_indices_single_thread() {
        let sum = AtomicU64::new(0);
        parallel_for(1, 100, 8, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn covers_all_indices_multi_thread() {
        let hits = (0..1000).map(|_| AtomicU64::new(0)).collect::<Vec<_>>();
        parallel_for(4, 1000, 7, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn zero_items_is_noop() {
        parallel_for(4, 0, 16, |_| panic!("should not run"));
    }
}
