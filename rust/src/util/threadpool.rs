//! Persistent worker pool for head-varlen attention load balancing.
//!
//! FlashInfer balances head-wise dynamic budgets by flattening the
//! (sequence, head) dimension into a single work list and keeping its
//! balanced varlen workers *resident*; we do the same with a pool of
//! parked std threads draining a chunked atomic ticket queue. The pool
//! is created once per [`crate::coordinator::engine::Engine`] and reused
//! for every layer of every batched decode step, so the spawn/join
//! fixed cost that used to scale with `layers × steps` is paid once —
//! [`ThreadPool::spawned_threads`] is the observable: it stays flat
//! across rounds (asserted by `rust/tests/threadpool_stress.rs`).
//!
//! Lifecycle: [`ThreadPool::new`] spawns nothing; resident workers are
//! grown lazily by the first round that needs them (and after
//! [`ThreadPool::set_threads`] raises the target — shrinking only
//! lowers the target, residents are parked, never torn down mid-life).
//! Each [`ThreadPool::run`] round publishes a generation-stamped job
//! under the pool mutex, wakes the workers, lets the caller drain
//! tickets too, and blocks at a completion barrier until every resident
//! worker has left the round — the `std::thread::scope` guarantee with
//! the threads outliving the scope, which is what makes the
//! lifetime-erased job reference sound. A worker panic is captured, the
//! round still drains to the barrier, and the panic is re-raised on the
//! caller with the pool intact for subsequent rounds —
//! [`ThreadPool::run_quarantined`] instead *contains* each panic to its
//! index and hands the captured payloads back, the fault-domain variant
//! (DESIGN.md §14) for callers that fail one item, not the round.
//! Dropping the pool flags shutdown, wakes, and joins every worker.
//!
//! Determinism contract: `threads == 1` — and any round with
//! `n <= chunk` — executes inline on the caller thread, the sequential
//! bit-exactness reference. For `threads > 1` the *assignment* of
//! tickets to threads is racy by design; callers that must be bit-exact
//! (the engine's phase-(b) attention drain) make every ticket's work
//! independent and merge results in flattened item order at the phase
//! barrier, so logits, stats, and telemetry are identical for any
//! worker count (`TWILIGHT_THREADS=1` ≡ `=N`; pinned by
//! `rust/tests/golden_decode.rs` and `rust/tests/parallel_decode.rs`).

use std::any::Any;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// The work function of one round; its borrows are lifetime-erased for
/// the resident workers (see the safety argument in [`ThreadPool::run`]).
type Task<'a> = dyn Fn(usize) + Sync + 'a;

/// One round's job descriptor, copied out of the slot by each worker.
#[derive(Clone, Copy)]
struct JobDesc {
    work: &'static Task<'static>,
    n: usize,
    chunk: usize,
    /// Resident workers allowed to drain this round (`threads - 1` at
    /// the round's target): after a `set_threads` shrink, surplus
    /// residents join the barrier but never pull a ticket.
    helpers: usize,
}

/// Round state, guarded by the pool mutex.
struct Slot {
    /// Round counter; workers join a round exactly once by comparing it
    /// against the last generation they executed.
    generation: u64,
    /// The active round's job (`None` between rounds).
    job: Option<JobDesc>,
    /// Resident workers that have not yet left the active round.
    outstanding: usize,
    /// Resident worker threads (excludes the caller).
    resident: usize,
    /// Tells parked workers to exit.
    shutdown: bool,
    /// First panic captured during the active round.
    panic: Option<Box<dyn Any + Send>>,
}

struct Shared {
    slot: Mutex<Slot>,
    /// Workers park here between rounds.
    start: Condvar,
    /// The caller parks here at the round's completion barrier.
    done: Condvar,
    /// Ticket queue for the active round (chunked indices into `0..n`).
    next: AtomicUsize,
    /// Round admission counter: the first `JobDesc::helpers` residents
    /// to join the round drain tickets, the rest only hit the barrier.
    admitted: AtomicUsize,
}

/// Pull tickets for `job` until the queue runs dry, capturing the first
/// panic into the slot (the round still reaches its barrier).
fn drain(shared: &Shared, job: JobDesc) {
    loop {
        let start = shared.next.fetch_add(job.chunk, Ordering::Relaxed);
        if start >= job.n {
            break;
        }
        let end = (start + job.chunk).min(job.n);
        let work = job.work;
        if let Err(payload) = catch_unwind(AssertUnwindSafe(|| {
            for i in start..end {
                work(i);
            }
        })) {
            let mut slot = shared.slot.lock().unwrap();
            if slot.panic.is_none() {
                slot.panic = Some(payload);
            }
            break;
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    let mut last_gen = 0u64;
    loop {
        let job = {
            let mut slot = shared.slot.lock().unwrap();
            loop {
                if slot.shutdown {
                    return;
                }
                if let Some(job) = slot.job {
                    if slot.generation != last_gen {
                        last_gen = slot.generation;
                        break job;
                    }
                }
                slot = shared.start.wait(slot).unwrap();
            }
        };
        if shared.admitted.fetch_add(1, Ordering::Relaxed) < job.helpers {
            drain(&shared, job);
        }
        let mut slot = shared.slot.lock().unwrap();
        slot.outstanding -= 1;
        if slot.outstanding == 0 {
            shared.done.notify_one();
        }
    }
}

/// A persistent, dependency-free worker pool: parked std threads, a
/// chunked atomic ticket queue, and a generation counter per round.
pub struct ThreadPool {
    shared: Arc<Shared>,
    /// Target parallelism including the caller thread.
    target: AtomicUsize,
    /// Worker threads ever spawned — the reuse instrumentation hook.
    spawned: AtomicUsize,
    /// Serializes rounds (a round owns the slot/ticket state end to end).
    run_lock: Mutex<()>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl ThreadPool {
    /// Create a pool targeting `threads` total parallelism (caller
    /// included). No thread is spawned until a round needs one.
    pub fn new(threads: usize) -> ThreadPool {
        ThreadPool {
            shared: Arc::new(Shared {
                slot: Mutex::new(Slot {
                    generation: 0,
                    job: None,
                    outstanding: 0,
                    resident: 0,
                    shutdown: false,
                    panic: None,
                }),
                start: Condvar::new(),
                done: Condvar::new(),
                next: AtomicUsize::new(0),
                admitted: AtomicUsize::new(0),
            }),
            target: AtomicUsize::new(threads.max(1)),
            spawned: AtomicUsize::new(0),
            run_lock: Mutex::new(()),
            handles: Mutex::new(Vec::new()),
        }
    }

    /// Pool sized by `TWILIGHT_THREADS` / available parallelism.
    pub fn with_default_threads() -> ThreadPool {
        ThreadPool::new(default_threads())
    }

    /// Target parallelism (caller included); never below 1.
    pub fn threads(&self) -> usize {
        self.target.load(Ordering::Relaxed).max(1)
    }

    /// Retarget the pool. Growth is lazy (workers spawn on the next
    /// round that needs them); shrinking parks the surplus residents but
    /// never tears them down — `threads == 1` bypasses them entirely.
    pub fn set_threads(&self, threads: usize) {
        self.target.store(threads.max(1), Ordering::Relaxed);
    }

    /// Worker threads ever created by this pool. A reused pool reports a
    /// constant value across rounds (at most `threads() - 1`, since the
    /// caller drains tickets too); a spawn-per-round regression makes
    /// this grow linearly — the stress test's key assertion.
    pub fn spawned_threads(&self) -> usize {
        self.spawned.load(Ordering::Relaxed)
    }

    /// Pooled rounds executed so far (inline rounds — `threads == 1` or
    /// `n <= chunk` — bypass the pool and are not counted).
    pub fn rounds(&self) -> u64 {
        self.shared.slot.lock().unwrap().generation
    }

    /// Execute `work(i)` for every `i in 0..n`, dynamically
    /// load-balanced in chunks of `chunk` tickets across the caller plus
    /// the resident workers. Blocks until every index has been executed
    /// exactly once. If any invocation panics, the first captured panic
    /// is re-raised here after the round's barrier (the pool survives
    /// for subsequent rounds). Rounds are serialized; `work` must not
    /// call back into the same pool (it would deadlock on the round
    /// lock) — the engine never nests rounds.
    pub fn run<F: Fn(usize) + Sync>(&self, n: usize, chunk: usize, work: F) {
        let chunk = chunk.max(1);
        let threads = self.threads();
        if threads == 1 || n <= chunk {
            for i in 0..n {
                work(i);
            }
            return;
        }
        // Pooled rounds only: one span + one latency observation per
        // round. The inline path above stays untouched (it is the
        // threads == 1 hot path whose allocation budget is pinned).
        let round_mark = crate::obs::trace::mark();
        let round_t0 = std::time::Instant::now();
        // A previous round's re-raised panic unwinds through the guard
        // and poisons the lock; the pool is still fully consistent then
        // (rounds always complete their barrier), so clear the poison.
        let round_guard = self.run_lock.lock().unwrap_or_else(|e| e.into_inner());
        // Helpers the round can actually use: one per ticket beyond the
        // caller's, capped by the target. Lazily grown, kept forever.
        self.ensure_workers((threads - 1).min(n.saturating_sub(1)));
        let task: &Task<'_> = &work;
        // SAFETY: the erased reference only lives in `Slot::job` for the
        // duration of this round, and the barrier below does not let
        // this function return until `outstanding == 0` — i.e. until no
        // worker can touch `work` (or anything it borrows) ever again
        // (workers only read the job within the generation they joined).
        // This is the `std::thread::scope` guarantee with the threads
        // outliving the scope instead of the scope outliving the
        // threads.
        let task: &'static Task<'static> =
            unsafe { std::mem::transmute::<&Task<'_>, &'static Task<'static>>(task) };
        let job = JobDesc { work: task, n, chunk, helpers: threads - 1 };
        {
            let mut slot = self.shared.slot.lock().unwrap();
            // No worker is in a round here (the previous round's barrier
            // completed before its `run` returned), so resetting the
            // ticket queue and admission counter cannot race stale
            // `fetch_add`s.
            self.shared.next.store(0, Ordering::Relaxed);
            self.shared.admitted.store(0, Ordering::Relaxed);
            slot.generation = slot.generation.wrapping_add(1);
            slot.job = Some(job);
            slot.outstanding = slot.resident;
        }
        self.shared.start.notify_all();
        // The caller is a worker too: threads == 1 degenerates to the
        // inline loop above, threads == k uses k - 1 resident threads.
        drain(&self.shared, job);
        let panic = {
            let mut slot = self.shared.slot.lock().unwrap();
            while slot.outstanding != 0 {
                slot = self.shared.done.wait(slot).unwrap();
            }
            slot.job = None;
            slot.panic.take()
        };
        drop(round_guard);
        crate::obs::trace::record_since(
            round_mark,
            crate::obs::trace::Stage::PoolRound,
            crate::obs::trace::ctx(),
        );
        {
            use std::sync::OnceLock;
            static ROUND_HIST: OnceLock<&'static crate::obs::metrics::LogHist> = OnceLock::new();
            let h = ROUND_HIST.get_or_init(|| {
                crate::obs::metrics::histogram(
                    "twilight_pool_round_seconds",
                    "wall seconds of one pooled attention round (publish to barrier)",
                )
            });
            h.observe(round_t0.elapsed().as_secs_f64());
        }
        if let Some(payload) = panic {
            std::panic::resume_unwind(payload);
        }
    }

    /// Like [`ThreadPool::run`], but a panic in one index is *contained*
    /// to that index instead of poisoning the whole round: every other
    /// index still executes, and the captured panics come back sorted by
    /// index for the caller to map onto per-item failures (the engine
    /// turns them into `CacheError::WorkerPanic` so one poisoned request
    /// cannot take down its batch neighbors — DESIGN.md §14). An empty
    /// return vector means every index completed.
    pub fn run_quarantined<F: Fn(usize) + Sync>(
        &self,
        n: usize,
        chunk: usize,
        work: F,
    ) -> Vec<(usize, Box<dyn Any + Send>)> {
        let panics: Mutex<Vec<(usize, Box<dyn Any + Send>)>> = Mutex::new(Vec::new());
        self.run(n, chunk, |i| {
            if let Err(payload) = catch_unwind(AssertUnwindSafe(|| work(i))) {
                let mut p = panics.lock().unwrap_or_else(|e| e.into_inner());
                p.push((i, payload));
            }
        });
        let mut panics = panics.into_inner().unwrap_or_else(|e| e.into_inner());
        panics.sort_unstable_by_key(|&(i, _)| i);
        panics
    }

    fn ensure_workers(&self, want: usize) {
        let mut handles = self.handles.lock().unwrap();
        while handles.len() < want {
            let shared = Arc::clone(&self.shared);
            let idx = self.spawned.load(Ordering::Relaxed);
            let handle = std::thread::Builder::new()
                .name(format!("twilight-attn-{idx}"))
                .spawn(move || worker_loop(shared))
                .expect("failed to spawn attention worker");
            // Count it resident only once the spawn succeeded, so a
            // failed spawn can never strand the round barrier waiting on
            // a worker that does not exist.
            self.shared.slot.lock().unwrap().resident += 1;
            self.spawned.fetch_add(1, Ordering::Relaxed);
            handles.push(handle);
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut slot = match self.shared.slot.lock() {
                Ok(guard) => guard,
                Err(poisoned) => poisoned.into_inner(),
            };
            slot.shutdown = true;
        }
        self.shared.start.notify_all();
        let handles = match self.handles.get_mut() {
            Ok(hs) => std::mem::take(hs),
            Err(poisoned) => std::mem::take(poisoned.into_inner()),
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Number of workers to use by default: respects `TWILIGHT_THREADS`,
/// falling back to available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("TWILIGHT_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_all_indices_single_thread() {
        let pool = ThreadPool::new(1);
        let sum = AtomicU64::new(0);
        pool.run(100, 8, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
        assert_eq!(pool.spawned_threads(), 0, "threads == 1 must run inline");
    }

    #[test]
    fn covers_all_indices_multi_thread() {
        let pool = ThreadPool::new(4);
        let hits = (0..1000).map(|_| AtomicU64::new(0)).collect::<Vec<_>>();
        pool.run(1000, 7, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        assert!(pool.spawned_threads() <= 3, "caller participates in the round");
    }

    #[test]
    fn zero_items_is_noop() {
        let pool = ThreadPool::new(4);
        pool.run(0, 16, |_| panic!("should not run"));
        assert_eq!(pool.spawned_threads(), 0);
    }

    #[test]
    fn rounds_reuse_resident_workers() {
        let pool = ThreadPool::new(4);
        pool.run(64, 1, |_| {});
        let spawned = pool.spawned_threads();
        assert!(spawned >= 1 && spawned <= 3);
        for _ in 0..50 {
            pool.run(64, 1, |_| {});
        }
        assert_eq!(pool.spawned_threads(), spawned, "threads must spawn once, not per round");
        assert_eq!(pool.rounds(), 51);
    }

    #[test]
    fn quarantined_panic_contains_to_one_index() {
        let pool = ThreadPool::new(4);
        let hits = (0..64).map(|_| AtomicU64::new(0)).collect::<Vec<_>>();
        let panics = pool.run_quarantined(64, 1, |i| {
            if i == 7 {
                panic!("poisoned item");
            }
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(panics.len(), 1, "exactly the poisoned item is quarantined");
        assert_eq!(panics[0].0, 7);
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), u64::from(i != 7), "sibling {i}");
        }
        let sum = AtomicU64::new(0);
        pool.run(100, 3, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950, "pool survives a quarantined round");
    }

    #[test]
    fn quarantined_inline_path_contains_too() {
        let pool = ThreadPool::new(1);
        let panics = pool.run_quarantined(8, 1, |i| {
            if i % 2 == 0 {
                panic!("even ticket {i}");
            }
        });
        assert_eq!(panics.iter().map(|p| p.0).collect::<Vec<_>>(), vec![0, 2, 4, 6]);
        assert_eq!(pool.spawned_threads(), 0, "inline path must not spawn");
    }

    #[test]
    fn panic_is_reraised_and_pool_survives() {
        let pool = ThreadPool::new(4);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.run(64, 1, |i| {
                if i == 7 {
                    panic!("ticket 7 failed");
                }
            });
        }));
        assert!(caught.is_err(), "worker panic must reach the caller");
        let sum = AtomicU64::new(0);
        pool.run(100, 3, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950, "pool must survive a panicked round");
    }
}
