//! Leveled stderr logger with a global level, timestamped relative to
//! process start. Deliberately tiny: the coordinator needs structured-ish
//! progress lines, not a logging framework.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level_from_str(s: &str) -> Level {
    match s.to_ascii_lowercase().as_str() {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => Level::Info,
    }
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

fn start() -> Instant {
    static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Initialize the relative-time origin; call at process start.
pub fn init() {
    let _ = start();
}

#[doc(hidden)]
pub fn log(l: Level, module: &str, msg: std::fmt::Arguments) {
    if enabled(l) {
        let t = start().elapsed().as_secs_f64();
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {tag} {module}] {msg}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn parse_levels() {
        assert_eq!(level_from_str("trace"), Level::Trace);
        assert_eq!(level_from_str("bogus"), Level::Info);
    }
}
