//! Leveled stderr logger with a global level, timestamped relative to
//! process start. Deliberately tiny: the coordinator needs structured-ish
//! progress lines, not a logging framework.
//!
//! Two output modes: human-readable text (default) and JSON-lines
//! (`--log-json` / [`set_json`]), where every line is one JSON object
//! `{"t":…,"level":…,"module":…,"msg":…}` — plus one key per structured
//! field for [`log_kv`] — so serving logs are machine-parseable.

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static JSON_MODE: AtomicBool = AtomicBool::new(false);

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Switch log output to JSON-lines (one JSON object per line).
pub fn set_json(on: bool) {
    JSON_MODE.store(on, Ordering::Relaxed);
}

pub fn json_mode() -> bool {
    JSON_MODE.load(Ordering::Relaxed)
}

pub fn level_from_str(s: &str) -> Level {
    match s.to_ascii_lowercase().as_str() {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => Level::Info,
    }
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

fn start() -> Instant {
    static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Initialize the relative-time origin; call at process start.
pub fn init() {
    let _ = start();
}

fn tag(l: Level) -> &'static str {
    match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    }
}

#[doc(hidden)]
pub fn log(l: Level, module: &str, msg: std::fmt::Arguments) {
    if enabled(l) {
        let t = start().elapsed().as_secs_f64();
        if json_mode() {
            let line = crate::util::json::obj(vec![
                ("t", crate::util::json::Json::Num(t)),
                ("level", crate::util::json::s(tag(l).trim_end())),
                ("module", crate::util::json::s(module)),
                ("msg", crate::util::json::s(&msg.to_string())),
            ]);
            eprintln!("{}", line.to_string());
        } else {
            eprintln!("[{t:9.3}s {} {module}] {msg}", tag(l));
        }
    }
}

/// Structured log line: `msg` plus numeric `key=value` fields. Text mode
/// appends `k=v` pairs; JSON mode merges each field as its own key into
/// the line object — the obs snapshot lines route through here.
pub fn log_kv(l: Level, module: &str, msg: &str, fields: &[(&str, f64)]) {
    if !enabled(l) {
        return;
    }
    let t = start().elapsed().as_secs_f64();
    if json_mode() {
        let mut kv: Vec<(&str, crate::util::json::Json)> = vec![
            ("t", crate::util::json::Json::Num(t)),
            ("level", crate::util::json::s(tag(l).trim_end())),
            ("module", crate::util::json::s(module)),
            ("msg", crate::util::json::s(msg)),
        ];
        for &(k, v) in fields {
            kv.push((k, crate::util::json::Json::Num(v)));
        }
        let line = crate::util::json::obj(kv);
        eprintln!("{}", line.to_string());
    } else {
        use std::fmt::Write;
        let mut line = String::with_capacity(64 + fields.len() * 16);
        for &(k, v) in fields {
            let _ = write!(line, " {k}={v:.6}");
        }
        eprintln!("[{t:9.3}s {} {module}] {msg}{line}", tag(l));
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn parse_levels() {
        assert_eq!(level_from_str("trace"), Level::Trace);
        assert_eq!(level_from_str("bogus"), Level::Info);
    }

    #[test]
    fn kv_lines_emit_in_both_modes() {
        // Smoke: neither mode may panic, and json mode round-trips
        // through the shared Json writer (escaping checked there).
        set_level(Level::Info);
        log_kv(Level::Info, "test", "snapshot", &[("queue", 3.0), ("tpot_ema_s", 0.0125)]);
        set_json(true);
        log_kv(Level::Info, "test", "snap \"quoted\"", &[("queue", 3.0)]);
        log(Level::Info, "test", format_args!("plain {}", 7));
        set_json(false);
    }
}
