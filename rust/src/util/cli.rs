//! Tiny command-line argument parser (clap is not in the offline crate
//! set). Supports `--key value`, `--key=value`, boolean `--flag`, and
//! positional arguments, with typed accessors and defaulting.

use std::collections::BTreeMap;

/// Parsed arguments: named options plus positionals, in order.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    /// `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(args: I, flag_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&body) {
                    out.flags.push(body.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        out.flags.push(body.to_string());
                    } else {
                        let v = it.next().unwrap();
                        out.opts.insert(body.to_string(), v);
                    }
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env(flag_names: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated list of usize, e.g. `--lens 1024,4096,16384`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            Some(s) => s.split(',').filter_map(|t| t.trim().parse().ok()).collect(),
            None => default.to_vec(),
        }
    }

    /// Comma-separated list of f64.
    pub fn f64_list_or(&self, name: &str, default: &[f64]) -> Vec<f64> {
        match self.get(name) {
            Some(s) => s.split(',').filter_map(|t| t.trim().parse().ok()).collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str], flags: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()), flags)
    }

    #[test]
    fn key_value_forms() {
        let a = parse(&["--x", "3", "--y=4", "pos1"], &[]);
        assert_eq!(a.usize_or("x", 0), 3);
        assert_eq!(a.usize_or("y", 0), 4);
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn flags_and_defaults() {
        let a = parse(&["--verbose", "--n", "2"], &["verbose"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.usize_or("n", 0), 2);
        assert_eq!(a.f64_or("p", 0.95), 0.95);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["--a", "--b"], &[]);
        assert!(a.flag("a"));
        assert!(a.flag("b"));
    }

    #[test]
    fn lists() {
        let a = parse(&["--lens", "1,2,3", "--ps", "0.8, 0.9"], &[]);
        assert_eq!(a.usize_list_or("lens", &[]), vec![1, 2, 3]);
        assert_eq!(a.f64_list_or("ps", &[]), vec![0.8, 0.9]);
        assert_eq!(a.usize_list_or("missing", &[7]), vec![7]);
    }
}
