//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so we own a small, well-understood
//! generator: xoshiro256** seeded through SplitMix64 (the reference
//! initialization recommended by the xoshiro authors). Everything in the
//! repo that needs randomness — workload generation, property tests,
//! synthetic weights — goes through this module so runs are reproducible
//! from a single `u64` seed.

/// xoshiro256** PRNG with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to expand the seed into the full state, per Vigna.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free (biased < 2^-64 for our n) mapping.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal sample (Box–Muller; one value per call, simple over fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal sample as f32 with mean/std.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Exponential inter-arrival sample with rate `lambda` (events/sec).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Zipf-distributed integer in `[0, n)` with exponent `s` (s >= 0).
    /// Used by workload generation for skewed prefix popularity.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        // Inverse-CDF over the (small) support; n here is at most a few
        // thousand distinct prompts so a linear scan is fine.
        let mut norm = 0.0;
        for k in 1..=n {
            norm += 1.0 / (k as f64).powf(s);
        }
        let mut u = self.f64() * norm;
        for k in 1..=n {
            u -= 1.0 / (k as f64).powf(s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n), unordered.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        // Floyd's algorithm.
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if out.contains(&t) {
                out.push(j);
            } else {
                out.push(t);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            let x = r.below(8);
            assert!(x < 8);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        for _ in 0..100 {
            let mut s = r.sample_indices(50, 10);
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 10);
            assert!(s.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[r.zipf(10, 1.2)] += 1;
        }
        assert!(counts[0] > counts[9] * 3, "{counts:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
