//! Owned substrate: utilities the offline crate-set requires us to build
//! ourselves (no serde / clap / rand / criterion / proptest available).

pub mod cli;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;

/// Round `n` up to the next multiple of `m` (`m > 0`).
#[inline]
pub fn round_up(n: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    n.div_ceil(m) * m
}

/// Integer ceiling division.
#[inline]
pub fn ceil_div(n: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    n.div_ceil(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basic() {
        assert_eq!(round_up(0, 16), 0);
        assert_eq!(round_up(1, 16), 16);
        assert_eq!(round_up(16, 16), 16);
        assert_eq!(round_up(17, 16), 32);
    }

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }
}
