//! Mini property-based testing harness (proptest is not in the offline
//! crate set). A property is a closure over a seeded [`Rng`]; the runner
//! executes many cases and, on panic or returned failure, reports the
//! case seed so the exact input can be replayed with
//! `TWILIGHT_PROP_SEED=<seed> cargo test <name>`.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, base_seed: 0xC0FFEE }
    }
}

/// Run `prop` for `cfg.cases` seeded cases. The property returns
/// `Err(message)` (or panics) to signal failure.
pub fn check<F: Fn(&mut Rng) -> Result<(), String>>(name: &str, cfg: Config, prop: F) {
    // Replay hook: run exactly one seed if requested.
    if let Ok(s) = std::env::var("TWILIGHT_PROP_SEED") {
        if let Ok(seed) = s.parse::<u64>() {
            let mut rng = Rng::new(seed);
            if let Err(e) = prop(&mut rng) {
                panic!("property '{name}' failed on replay seed {seed}: {e}");
            }
            return;
        }
    }
    for case in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(e) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (replay with TWILIGHT_PROP_SEED={seed}): {e}"
            );
        }
    }
}

/// Run with the default config.
pub fn check_default<F: Fn(&mut Rng) -> Result<(), String>>(name: &str, prop: F) {
    check(name, Config::default(), prop)
}

/// Assert helper producing `Result` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check_default("reflexive", |rng| {
            let x = rng.below(100);
            if x == x {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failure_with_seed() {
        check("always-fails", Config { cases: 2, base_seed: 1 }, |_| Err("nope".into()));
    }
}
