//! Minimal JSON parser and writer.
//!
//! Used for configs, the server wire protocol, workload traces, and bench
//! result files. Supports the full JSON grammar (objects, arrays, strings
//! with escapes, numbers, booleans, null); numbers are stored as `f64`.
//! Object key order is preserved (insertion order) so emitted configs and
//! results diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Json)>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors ------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Look up a key in an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` chained with string access.
    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(|v| v.as_str())
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.as_f64())
    }

    pub fn get_usize(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(|v| v.as_usize())
    }

    pub fn get_bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(|v| v.as_bool())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !kv.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders for emitting results.
pub fn obj(kv: Vec<(&str, Json)>) -> Json {
    Json::Obj(kv.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn arr<I: IntoIterator<Item = Json>>(it: I) -> Json {
    Json::Arr(it.into_iter().collect())
}

/// Array of numbers from any float iterator.
pub fn arr_f64<I: IntoIterator<Item = f64>>(it: I) -> Json {
    Json::Arr(it.into_iter().map(Json::Num).collect())
}

/// Map object into a BTreeMap for order-insensitive comparison in tests.
pub fn to_map(j: &Json) -> Option<BTreeMap<String, Json>> {
    match j {
        Json::Obj(kv) => Some(kv.iter().cloned().collect()),
        _ => None,
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed by our own
                            // writers; map lone surrogates to U+FFFD.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.b[self.i..];
                    let s = std::str::from_utf8(rest).map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for t in ["null", "true", "false", "0", "-1.5", "3e2", "\"hi\\n\""] {
            let v = Json::parse(t).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x", "d": true}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get_bool("d"), Some(true));
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::parse(r#"{"z": 1, "a": 2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"z":1,"a":2}"#);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ✓""#).unwrap();
        assert_eq!(v.as_str(), Some("café ✓"));
        let round = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, round);
    }

    #[test]
    fn pretty_parses_back() {
        let v = obj(vec![
            ("name", s("twilight")),
            ("nums", arr_f64([1.0, 2.5, 3.0])),
            ("nested", obj(vec![("ok", Json::Bool(true))])),
        ]);
        let p = v.pretty();
        assert_eq!(Json::parse(&p).unwrap(), v);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(8192.0).to_string(), "8192");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
