//! Summary statistics, histograms, and the micro-bench harness used by the
//! `benches/` binaries (criterion is not in the offline crate set, so the
//! timing loop lives here: warmup, fixed-time measurement, robust summary).

use std::time::{Duration, Instant};

/// Summary of a sample of f64 observations.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn from(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        let mean = v.iter().sum::<f64>() / n as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: v[0],
            p50: percentile_sorted(&v, 50.0),
            p90: percentile_sorted(&v, 90.0),
            p99: percentile_sorted(&v, 99.0),
            max: v[n - 1],
        }
    }
}

/// Percentile (0..=100) of a pre-sorted slice, linear interpolation.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi.min(sorted.len() - 1)] * frac
}

/// Percentile of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Fixed-bucket histogram over `[lo, hi)`; used for budget distributions.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub buckets: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
    pub count: u64,
    pub sum: f64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbuckets: usize) -> Histogram {
        assert!(hi > lo && nbuckets > 0);
        Histogram { lo, hi, buckets: vec![0; nbuckets], underflow: 0, overflow: 0, count: 0, sum: 0.0 }
    }

    pub fn add(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let nb = self.buckets.len();
            let w = (self.hi - self.lo) / nb as f64;
            let idx = (((x - self.lo) / w) as usize).min(nb - 1);
            self.buckets[idx] += 1;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Render a compact ASCII sparkline of bucket mass.
    pub fn sparkline(&self) -> String {
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.buckets.iter().copied().max().unwrap_or(0).max(1);
        self.buckets
            .iter()
            .map(|&b| BARS[(b as usize * (BARS.len() - 1)) / max as usize])
            .collect()
    }
}

/// One benchmark measurement: wall-clock per iteration, in seconds.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub secs: Summary,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.secs.mean * 1e6
    }
    pub fn mean_ms(&self) -> f64 {
        self.secs.mean * 1e3
    }
}

/// The bench harness: warm up for `warmup`, then time individual
/// invocations of `f` until `measure` elapses (at least `min_iters`).
pub fn bench<F: FnMut()>(name: &str, warmup: Duration, measure: Duration, min_iters: usize, mut f: F) -> BenchResult {
    let start = Instant::now();
    while start.elapsed() < warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed() < measure || samples.len() < min_iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
        if samples.len() > 100_000 {
            break;
        }
    }
    BenchResult { name: name.to_string(), iters: samples.len(), secs: Summary::from(&samples) }
}

/// Quick bench with default timing (0.2s warmup, 1s measure).
pub fn bench_quick<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench(name, Duration::from_millis(200), Duration::from_secs(1), 5, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interp() {
        let v = [0.0, 10.0];
        assert!((percentile(&v, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 100.0), 10.0);
    }

    #[test]
    fn histogram_counts() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(99.0);
        assert_eq!(h.count, 12);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert!(h.buckets.iter().all(|&b| b == 1));
        assert_eq!(h.sparkline().chars().count(), 10);
    }

    #[test]
    fn bench_runs() {
        let r = bench("noop", Duration::from_millis(1), Duration::from_millis(10), 3, || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters >= 3);
        assert!(r.secs.mean >= 0.0);
    }
}
