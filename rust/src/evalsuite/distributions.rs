//! Attention-weight distribution probes — the data behind Fig. 1/3
//! (focused vs diffuse), Fig. 4 (cumulative mass vs budget), and Fig. 11
//! (budget dynamism across queries/heads).

use crate::model::{DenseBackend, LayerBackend, Model};
use crate::pruner::topp::oracle_budget;
use crate::tensor::{dot, gemv, rmsnorm, softmax_inplace};

/// Exact attention weights of every head at the final position of
/// `prompt`, for `layer`. Returns `[n_heads][n]`.
pub fn final_position_weights(model: &Model, prompt: &[u32], layer: usize) -> Vec<Vec<f32>> {
    let cfg = &model.cfg;
    let mut b = DenseBackend::new(cfg);
    // Fill the cache (single-layer models use the O(n) path).
    if cfg.n_layers == 1 {
        for (pos, &tok) in prompt[..prompt.len() - 1].iter().enumerate() {
            let (k, v) = model.kv_from_embedding(tok, pos);
            b.append_kv(0, &k, &v);
        }
        let _ = model.decode_step(*prompt.last().unwrap(), prompt.len() - 1, &mut b);
    } else {
        for (pos, &tok) in prompt.iter().enumerate() {
            let _ = model.decode_step(tok, pos, &mut b);
        }
    }
    // Recompute the final token's q for `layer` by replaying the residual
    // stream (cheap: one forward without cache mutation).
    struct Replay<'a> {
        inner: &'a DenseBackend,
        q_capture: Vec<Vec<f32>>,
        layer_count: usize,
    }
    impl<'a> LayerBackend for Replay<'a> {
        fn append_kv(&mut self, _l: usize, _k: &[f32], _v: &[f32]) {}
        fn attend(&mut self, layer: usize, qs: &[f32]) -> Vec<f32> {
            self.q_capture.push(qs.to_vec());
            self.layer_count += 1;
            // Dense attention over the already-filled cache (minus the
            // token we are replaying, which is the last row).
            let c = &self.inner.cfg;
            let d = c.head_dim;
            let kvd = c.kv_dim();
            let n = self.inner.k[layer].len() / kvd;
            let group = c.group();
            let mut out = vec![0.0; c.q_dim()];
            for h in 0..c.n_heads {
                let kvh = h / group;
                let q = &qs[h * d..(h + 1) * d];
                let mut logits: Vec<f32> = (0..n)
                    .map(|t| {
                        dot(q, &self.inner.k[layer][t * kvd + kvh * d..t * kvd + (kvh + 1) * d])
                            / (d as f32).sqrt()
                    })
                    .collect();
                softmax_inplace(&mut logits);
                for (t, w) in logits.iter().enumerate() {
                    let v = &self.inner.v[layer][t * kvd + kvh * d..t * kvd + (kvh + 1) * d];
                    crate::tensor::axpy(*w, v, &mut out[h * d..(h + 1) * d]);
                }
            }
            out
        }
    }
    let mut replay = Replay { inner: &b, q_capture: Vec::new(), layer_count: 0 };
    let _ = model.decode_step(*prompt.last().unwrap(), prompt.len() - 1, &mut replay);
    let qs = &replay.q_capture[layer];
    // Weights per head over the full cache.
    let c = &model.cfg;
    let d = c.head_dim;
    let kvd = c.kv_dim();
    let n = b.k[layer].len() / kvd;
    let group = c.group();
    (0..c.n_heads)
        .map(|h| {
            let kvh = h / group;
            let q = &qs[h * d..(h + 1) * d];
            let mut w: Vec<f32> = (0..n)
                .map(|t| {
                    dot(q, &b.k[layer][t * kvd + kvh * d..t * kvd + (kvh + 1) * d])
                        / (d as f32).sqrt()
                })
                .collect();
            softmax_inplace(&mut w);
            w
        })
        .collect()
}

/// Entropy of a weight distribution (nats) — diffuseness measure.
pub fn entropy(w: &[f32]) -> f64 {
    -w.iter().filter(|&&x| x > 0.0).map(|&x| (x as f64) * (x as f64).ln()).sum::<f64>()
}

/// Cumulative attention mass after sorting descending — the Fig. 4 curve.
pub fn cumulative_mass(w: &[f32]) -> Vec<f32> {
    let mut sorted = w.to_vec();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut acc = 0.0;
    sorted
        .iter()
        .map(|&x| {
            acc += x;
            acc
        })
        .collect()
}

/// Oracle top-p budgets per head for one query (Fig. 11 head dynamism).
pub fn head_budgets(weights: &[Vec<f32>], p: f32) -> Vec<usize> {
    weights.iter().map(|w| oracle_budget(w, p)).collect()
}

/// The first-layer hidden state helper shared with tests: normed
/// embedding for a token.
pub fn normed_embedding(model: &Model, tok: u32) -> Vec<f32> {
    let c = &model.cfg;
    let x = model.embed_token(tok);
    if c.use_norm {
        let mut h = vec![0.0; c.d_model];
        rmsnorm(&x, &model.layers[0].ln1, c.norm_eps, &mut h);
        h
    } else {
        x
    }
}

/// Query vectors of the final token at layer 0 (for kernel-level probes).
pub fn layer0_queries(model: &Model, tok: u32, pos: usize) -> Vec<f32> {
    let c = &model.cfg;
    let h = normed_embedding(model, tok);
    let mut q = vec![0.0; c.q_dim()];
    gemv(&model.layers[0].wq, &h, None, &mut q);
    if c.use_rope {
        for hh in 0..c.n_heads {
            crate::tensor::rope_inplace(
                &mut q[hh * c.head_dim..(hh + 1) * c.head_dim],
                pos,
                c.rope_theta,
            );
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::retrieval::build_retrieval_model;
    use crate::util::rng::Rng;
    use crate::workload::{gen_niah, RetrievalVocab};

    #[test]
    fn retrieval_vs_aggregation_entropy_gap() {
        let v = RetrievalVocab::DEFAULT;
        let model = build_retrieval_model(v, 4096);
        let mut r = Rng::new(1);
        let g = gen_niah(&mut r, v, 512);
        let ws = final_position_weights(&model, &g.prompt, 0);
        // Head 0 = retrieval (focused), head 4 = aggregation (diffuse for
        // a NIAH query: uniform).
        let e_focused = entropy(&ws[0]);
        let e_diffuse = entropy(&ws[4]);
        assert!(e_focused < 1.0, "focused entropy {e_focused}");
        assert!(e_diffuse > 5.0, "diffuse entropy {e_diffuse}");
    }

    #[test]
    fn cumulative_mass_monotone_to_one() {
        let w = vec![0.5, 0.3, 0.2];
        let c = cumulative_mass(&w);
        assert!(c.windows(2).all(|p| p[1] >= p[0]));
        assert!((c.last().unwrap() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn head_budget_dynamism() {
        let v = RetrievalVocab::DEFAULT;
        let model = build_retrieval_model(v, 4096);
        let mut r = Rng::new(2);
        let g = gen_niah(&mut r, v, 512);
        let ws = final_position_weights(&model, &g.prompt, 0);
        let budgets = head_budgets(&ws, 0.9);
        let min = *budgets.iter().min().unwrap();
        let max = *budgets.iter().max().unwrap();
        assert!(max > min * 20, "budgets {budgets:?} lack dynamism");
    }
}
