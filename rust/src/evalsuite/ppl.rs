//! Perplexity evaluation of charlm under sparse attention — the PG-19
//! analog backing Fig. 2, Fig. 9, and Table 4.
//!
//! Teacher-forced decode over held-out corpus windows: every step runs
//! the full Select-then-Prune pipeline exactly as serving would, and the
//! next-token log-probability is accumulated.

use crate::coordinator::engine::Engine;
use crate::coordinator::SparseConfig;
use crate::model::sampler::log_prob;
use crate::model::Model;
use std::sync::Arc;

/// Result of one perplexity run.
#[derive(Clone, Debug)]
pub struct PplResult {
    pub label: String,
    pub ppl: f64,
    pub tokens: usize,
    pub avg_budget: f64,
}

/// Evaluate perplexity over `windows` windows of `window_len` tokens
/// drawn from `corpus`. The first `burn` predictions per window are
/// excluded (not enough context to be interesting).
pub fn eval_ppl(
    model: Arc<Model>,
    cfg: &SparseConfig,
    corpus: &[u32],
    windows: usize,
    window_len: usize,
    burn: usize,
) -> PplResult {
    assert!(corpus.len() >= windows * (window_len + 1));
    let mut engine = Engine::new(model, cfg.clone(), (window_len + 32) * 2);
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for w in 0..windows {
        let seq = &corpus[w * (window_len + 1)..(w + 1) * (window_len + 1)];
        let id = w as u64;
        // Teacher-forced decode; logits at step t predict token t+1.
        for t in 0..window_len {
            let logits = engine.decode_or_start(id, seq[t]).expect("OOM in ppl eval");
            if t >= burn {
                nll -= log_prob(&logits, seq[t + 1]);
                count += 1;
            }
        }
        engine.release(id);
    }
    PplResult {
        label: cfg.label(),
        ppl: (nll / count as f64).exp(),
        tokens: count,
        avg_budget: engine.stats.avg_kept(),
    }
}

impl Engine {
    /// Decode that starts the sequence on first use (ppl-eval
    /// convenience; serving uses `prefill`).
    pub fn decode_or_start(
        &mut self,
        id: u64,
        tok: u32,
    ) -> Result<Vec<f32>, crate::kvcache::CacheError> {
        if self.seq_len(id).is_none() {
            self.start_empty(id);
        }
        self.decode(id, tok)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::{random_model, tiny_config};
    use crate::selector::SelectorKind;

    fn corpus(n: usize) -> Vec<u32> {
        let mut r = crate::util::rng::Rng::new(5);
        (0..n).map(|_| r.below(16) as u32).collect()
    }

    #[test]
    fn dense_ppl_close_to_uniform_for_random_model() {
        let cfg = tiny_config();
        let model = Arc::new(random_model(&cfg, 3));
        let c = corpus(600);
        let r = eval_ppl(model, &SparseConfig::dense(), &c, 2, 128, 16);
        // Random model on random tokens: ppl near vocab size (16).
        assert!(r.ppl > 8.0 && r.ppl < 32.0, "ppl {}", r.ppl);
        assert_eq!(r.tokens, 2 * (128 - 16));
    }

    #[test]
    fn sparse_ppl_degrades_gracefully_with_budget() {
        let cfg = tiny_config();
        let model = Arc::new(random_model(&cfg, 4));
        let c = corpus(600);
        let dense = eval_ppl(model.clone(), &SparseConfig::dense(), &c, 2, 128, 16);
        let mut tiny = SparseConfig::baseline(SelectorKind::Quest, 16);
        tiny.skip_layers = 0;
        tiny.dense_below = 8;
        let sparse = eval_ppl(model, &tiny, &c, 2, 128, 16);
        // Sparse ppl may shift, but must remain finite and sane.
        assert!(sparse.ppl.is_finite());
        assert!(sparse.ppl > dense.ppl * 0.5);
        assert!(sparse.avg_budget <= 17.0);
    }
}
