//! Accuracy evaluation harness — produces the rows of the paper's tables
//! on the synthetic task suite (DESIGN.md §3, §5).

pub mod distributions;
pub mod ppl;

use crate::coordinator::engine::Engine;
use crate::coordinator::SparseConfig;
use crate::model::sampler::greedy;
use crate::model::Model;
use crate::util::rng::Rng;
use crate::workload::{gen_fwe, gen_multi_niah, gen_niah, GenRequest, RetrievalVocab, TaskKind};
use std::sync::Arc;

/// Accuracy + budget outcome for one (method, suite) cell.
#[derive(Clone, Debug)]
pub struct AccuracyResult {
    pub label: String,
    /// (task, correct, total) rows.
    pub per_task: Vec<(TaskKind, usize, usize)>,
    /// Mean final per-head budget (tokens) over sparse calls.
    pub avg_budget: f64,
    /// Mean stage-1 candidate budget.
    pub avg_candidates: f64,
    /// Fraction of candidates pruned by Twilight.
    pub prune_ratio: f64,
}

impl AccuracyResult {
    pub fn overall(&self) -> f64 {
        let c: usize = self.per_task.iter().map(|(_, c, _)| c).sum();
        let t: usize = self.per_task.iter().map(|(_, _, t)| t).sum();
        if t == 0 {
            0.0
        } else {
            c as f64 / t as f64
        }
    }

    pub fn task_accuracy(&self, task: TaskKind) -> f64 {
        self.per_task
            .iter()
            .find(|(k, _, _)| *k == task)
            .map(|(_, c, t)| *c as f64 / (*t).max(1) as f64)
            .unwrap_or(0.0)
    }
}

/// The evaluation suites (paper-benchmark analogs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Suite {
    /// LongBench analog: mixed tasks at one medium-long context.
    Longbench,
    /// RULER analog: NIAH-heavy at several long contexts.
    Ruler,
    /// Medium-context analog (GSM8K/COQA stand-in): short contexts.
    Medium,
}

impl Suite {
    pub fn parse(s: &str) -> Option<Suite> {
        match s.to_ascii_lowercase().as_str() {
            "longbench" => Some(Suite::Longbench),
            "ruler" => Some(Suite::Ruler),
            "medium" => Some(Suite::Medium),
            _ => None,
        }
    }
}

/// Generate the requests of a suite at `ctx_len`.
pub fn suite_requests(seed: u64, ctx_len: usize, n_per_task: usize) -> Vec<GenRequest> {
    let v = RetrievalVocab::DEFAULT;
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for _ in 0..n_per_task {
        out.push(gen_niah(&mut rng, v, ctx_len));
        out.push(gen_multi_niah(&mut rng, v, ctx_len, 4));
        out.push(gen_fwe(&mut rng, v, ctx_len, 6.0));
    }
    out
}

/// Run `requests` through a fresh engine configured with `cfg`; greedy
/// decode one answer token per request and score exact-match.
pub fn run_accuracy(
    model: Arc<Model>,
    cfg: &SparseConfig,
    requests: &[GenRequest],
    capacity_tokens: usize,
) -> AccuracyResult {
    let mut engine = Engine::new(model, cfg.clone(), capacity_tokens);
    let mut counts: Vec<(TaskKind, usize, usize)> = vec![
        (TaskKind::Niah, 0, 0),
        (TaskKind::MultiNiah, 0, 0),
        (TaskKind::Fwe, 0, 0),
    ];
    for (i, req) in requests.iter().enumerate() {
        let logits = engine.prefill(i as u64, &req.prompt).expect("prefill OOM");
        let pred = greedy(&logits);
        let row = counts.iter_mut().find(|(k, _, _)| *k == req.task).unwrap();
        row.2 += 1;
        if pred == req.answer {
            row.1 += 1;
        }
        engine.release(i as u64);
    }
    AccuracyResult {
        label: cfg.label(),
        per_task: counts,
        avg_budget: engine.stats.avg_kept(),
        avg_candidates: engine.stats.avg_candidates(),
        prune_ratio: engine.stats.prune_ratio(),
    }
}

/// Render a set of results as an aligned text table (the CLI/table
/// output format used by EXPERIMENTS.md).
pub fn render_table(title: &str, results: &[AccuracyResult]) -> String {
    let mut s = format!("## {title}\n");
    s.push_str(&format!(
        "{:<22} {:>7} {:>9} {:>7} {:>9} {:>10} {:>8}\n",
        "method", "niah", "multi", "fwe", "overall", "avg-budget", "pruned%"
    ));
    for r in results {
        s.push_str(&format!(
            "{:<22} {:>7.3} {:>9.3} {:>7.3} {:>9.3} {:>10.1} {:>8.1}\n",
            r.label,
            r.task_accuracy(TaskKind::Niah),
            r.task_accuracy(TaskKind::MultiNiah),
            r.task_accuracy(TaskKind::Fwe),
            r.overall(),
            r.avg_budget,
            r.prune_ratio * 100.0,
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::retrieval::build_retrieval_model;
    use crate::selector::SelectorKind;

    #[test]
    fn accuracy_suite_shapes_hold() {
        // The core Table-2 shape on a small instance: Twilight matches
        // dense accuracy at a fraction of the budget; a starved fixed
        // budget loses on FWE.
        let model = Arc::new(build_retrieval_model(RetrievalVocab::DEFAULT, 8192));
        let reqs = suite_requests(11, 512, 3);
        let dense = run_accuracy(model.clone(), &SparseConfig::dense(), &reqs, 1 << 14);
        let mut twi = SparseConfig::twilight(SelectorKind::Quest, 0.95);
        twi.skip_layers = 0;
        twi.dense_below = 32;
        let twi_r = run_accuracy(model.clone(), &twi, &reqs, 1 << 14);
        assert!((dense.overall() - 1.0).abs() < 1e-9, "dense must be perfect");
        assert!(twi_r.overall() >= 0.8, "twilight overall {}", twi_r.overall());
        assert!(twi_r.avg_budget > 0.0);
        let table = render_table("test", &[dense, twi_r]);
        assert!(table.contains("avg-budget"));
    }
}
